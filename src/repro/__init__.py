"""MIRAGE-on-JAX: iterative Map/Reduce frequent subgraph mining as a
multi-pod TPU framework.  See DESIGN.md for the architecture."""

__version__ = "0.1.0"
