"""MIRAGE-on-JAX: iterative Map/Reduce frequent subgraph mining as a
multi-pod TPU framework.  See README.md / DESIGN.md."""

__version__ = "0.1.0"
