"""Bit-packed graph bitsets: pack/unpack, lane-AND/OR, SWAR popcount.

The map phase's hot signal is boolean-per-graph — "does candidate c have
at least one embedding in graph g?" (MIRAGE §III-C).  Carrying it as
int32 lanes wastes 32x the HBM traffic and shuffle payload it needs;
DIMSpan (arXiv 1703.01910) shows bit-level compression of exactly this
state is what keeps distributed FSM in-memory and network-light.

Layout contract (DESIGN.md §12):

* a length-``n`` bit vector packs to ``ceil(n / 32)`` ``uint32`` words,
* bit ``i`` lives in word ``i // 32`` at position ``i % 32`` (LSB-first),
* pad bits beyond ``n`` are ZERO — producers guarantee it, and consumers
  that cannot (e.g. after a lane-OR with foreign words) re-mask with
  :func:`tail_mask`.

Every helper dispatches on the input type: jax arrays (including
tracers, so the helpers inline into Pallas kernels and jitted programs)
use ``jnp``; anything else uses host numpy.  The same source of truth
therefore serves the fused kernel, the reduce shuffle, the wire codec,
and the host-side oracles — which is what makes "packed is bit-identical
to dense" checkable instead of aspirational.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["WORD", "n_words", "pack_bits", "unpack_bits", "popcount",
           "tail_mask", "lane_and", "lane_or", "packed_any_count",
           "support_path_cost_model"]

WORD = 32

Array = Union[np.ndarray, jax.Array]


def _xp(x):
    return jnp if isinstance(x, jax.Array) else np


def n_words(n: int) -> int:
    """Number of uint32 words needed for an ``n``-bit vector."""
    return -(-int(n) // WORD)


def pack_bits(bits: Array, axis: int = -1) -> Array:
    """Pack a boolean (or 0/1 integer) array into uint32 words.

    The ``axis`` dimension of length ``n`` becomes ``ceil(n / 32)`` words,
    LSB-first; pad bits are zero.
    """
    xp = _xp(bits)
    b = xp.moveaxis(bits, axis, -1).astype(xp.uint32)
    n = b.shape[-1]
    w = n_words(n)
    pad = w * WORD - n
    if pad:
        b = xp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    b = b.reshape(b.shape[:-1] + (w, WORD))
    shifts = xp.arange(WORD, dtype=xp.uint32)
    words = xp.sum(b << shifts, axis=-1, dtype=xp.uint32)
    return xp.moveaxis(words, -1, axis)


def unpack_bits(words: Array, n: int, axis: int = -1) -> Array:
    """Inverse of :func:`pack_bits`: expand words back to ``n`` bools."""
    xp = _xp(words)
    w = xp.moveaxis(words, axis, -1).astype(xp.uint32)
    shifts = xp.arange(WORD, dtype=xp.uint32)
    bits = (w[..., None] >> shifts) & xp.uint32(1)
    bits = bits.reshape(bits.shape[:-2] + (-1,))[..., :n].astype(bool)
    return xp.moveaxis(bits, -1, axis)


def popcount(words: Array) -> Array:
    """Per-word population count (SWAR), returned as int32."""
    xp = _xp(words)
    x = words.astype(xp.uint32)
    x = x - ((x >> xp.uint32(1)) & xp.uint32(0x55555555))
    x = (x & xp.uint32(0x33333333)) + ((x >> xp.uint32(2)) & xp.uint32(0x33333333))
    x = (x + (x >> xp.uint32(4))) & xp.uint32(0x0F0F0F0F)
    return ((x * xp.uint32(0x01010101)) >> xp.uint32(24)).astype(xp.int32)


def tail_mask(n: int, words: Optional[int] = None) -> np.ndarray:
    """uint32 word vector with bits ``[0, n)`` set and the rest clear.

    ``words`` (>= ``n_words(n)``) pads the mask with all-zero words — the
    ragged-tail contract for a bit axis padded past ``n``.  Host numpy;
    pass through ``jnp.asarray`` (free at trace time) for device use.
    """
    w = n_words(n) if words is None else int(words)
    return pack_bits(np.arange(w * WORD, dtype=np.int64) < int(n))


def lane_and(a: Array, b: Array) -> Array:
    """Lane-wise AND of packed words (set intersection)."""
    return a & b


def lane_or(a: Array, b: Array) -> Array:
    """Lane-wise OR of packed words (set union; re-mask the tail if the
    operands disagree about pad bits)."""
    return a | b


def packed_any_count(words: Array, n: int, axis: int = -1) -> Array:
    """Count set bits of an ``n``-bit packed vector along ``axis`` —
    AND with the ragged-tail mask, popcount, sum.  int32."""
    xp = _xp(words)
    mask = tail_mask(n, words=np.shape(words)[axis])
    if xp is jnp:
        mask = jnp.asarray(mask)
    shape = [1] * np.ndim(words)
    shape[axis] = -1
    return xp.sum(popcount(words & mask.reshape(shape)), axis=axis,
                  dtype=xp.int32)


def support_path_cost_model(c: int, g: int, n_workers: int, *,
                            packed: bool) -> dict:
    """Modeled support-dimension bytes for one mining level.

    Counts the three places the boolean-per-graph signal travels:

    * ``hbm_bytes`` — the (C, G) verdict lanes a dense backend carries as
      int32 vs ``(C, ceil(G/32))`` uint32 bitset words,
    * ``collective_bytes`` — the per-worker verdict all-gather after
      ``reduce_scatter`` thresholding (int8 lanes vs packed words),
    * ``host_bytes`` — the per-worker gsup wire slice (int32 vs the
      2x-uint16 packed words of the sharded wire).

    This is the deterministic proxy gated by ``benchmarks/check_packed.py``
    (measured wall time is meaningless on a 1-core CPU container); the
    constants mirror ``level_step.wire_cost_model``.
    """
    w = max(int(n_workers), 1)
    ring = (w - 1) / w
    cs = -(-int(c) // w)
    if packed:
        hbm = c * n_words(g) * 4
        coll = ring * n_words(c) * 4
        host = -(-cs // 2) * 4
    else:
        hbm = c * g * 4
        coll = ring * c * 1
        host = cs * 4
    return {"hbm_bytes": float(hbm), "collective_bytes": float(coll),
            "host_bytes": float(host),
            "total_bytes": float(hbm + coll + host)}
