"""Pure-jnp oracles for the Pallas kernels (identical contracts).

These are thin wrappers over `repro.core.embedding` — the semantic source
of truth — reshaped to the kernels' (C, G) output contract so tests can
``assert_allclose(kernel(x), ref(x))`` across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_join_ref", "support_count_ref"]


def embedding_join_ref(meta, pol, pmask, src, dst, emask):
    """(C, G) matched / count — oracle for embedding_join_pallas."""
    # deferred: repro.core.embedding -> repro.core.__init__ -> mapreduce
    # -> kernels.ops -> this module would otherwise be a cycle, breaking
    # `import repro.kernels.ops` as the first repro import
    from repro.core.embedding import join_valid

    def one(cand):
        parent, stub, to, fwd, tidx = (cand[0], cand[1], cand[2], cand[3],
                                       cand[4])
        p = jnp.take(pol, parent, axis=0)
        pm = jnp.take(pmask, parent, axis=0).astype(bool)
        s = jnp.take(src, tidx, axis=0)
        d = jnp.take(dst, tidx, axis=0)
        em = jnp.take(emask, tidx, axis=0).astype(bool)
        valid = join_valid(p, pm, s, d, em, stub, to, fwd)
        return (valid.any(axis=(1, 2)).astype(jnp.int32),
                valid.sum(axis=(1, 2), dtype=jnp.int32))

    matched, count = jax.lax.map(one, meta)
    return matched, count


def support_count_ref(matched, count):
    """(C,) support / embed totals — oracle for support_count_pallas."""
    return (matched.sum(axis=1, dtype=jnp.int32),
            count.sum(axis=1, dtype=jnp.int32))
