"""Pallas TPU kernel: per-level embedding join (OL intersection).

LEGACY TWO-LAUNCH PATH — this join kernel plus ``support_count.py``'s
reduction survive as the on-device oracle/fallback (`backend="pallas"`).
The production map phase is ``fused_level.py``, which performs the join
AND the per-candidate reduction in one launch, eliminating this
pipeline's two (C, G) int32 HBM intermediates (DESIGN.md §6).

This is the mapper's inner loop (paper Fig. 7 line 4 / Fig. 6): for every
candidate c = (parent, stub, to, fwd, triple) and every graph g of the
partition, decide which (parent-embedding m, edge-occurrence f) pairs
join, and emit per-graph ``matched`` / ``match-count``.

TPU adaptation notes (vs the paper's Java loop — see DESIGN.md §2/§5):

  * One kernel launch covers the *whole level* (all C candidates): the
    grid is ``(C, G/TG)`` and a **scalar-prefetched** candidate table
    drives data-dependent BlockSpec index maps — candidate c streams the
    OL tile of *its own parent* ``meta[c,0]`` and the edge-OL tile of its
    own label triple ``meta[c,4]`` from HBM into VMEM.  This is the
    block-sparse-style dispatch that replaces per-candidate host calls.
  * The join is compare/mask work — VPU, not MXU.  Block shapes are
    picked for VMEM residency and 128-lane alignment of the trailing
    (F) axis; there is no matmul tiling to respect.
  * The O(M·F·K) membership test (forward edges must add a *new* vertex)
    is a K-step ``fori_loop`` with an (TG, M, F) accumulator instead of a
    materialized (TG, M, F, K) tensor — K ≤ 16 keeps the working set
    ≈ TG·M·F bytes, fitting VMEM for the default TG.

Shapes (one partition):
  pol   (P, G, M, K) int32   stacked parent OLs, PAD = -1
  pmask (P, G, M)    int8    embedding validity
  src   (T, G, F)    int32   edge-OL endpoints (directed triples)
  dst   (T, G, F)    int32
  emask (T, G, F)    int8
  meta  (C, 5)       int32   [parent, stub, to, fwd, triple]

Outputs:
  matched (C, G) int32 — 1 iff graph g holds >= 1 child embedding
  count   (C, G) int32 — number of joined pairs (cost-model signal)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_join_pallas", "DEFAULT_TILE_G"]

DEFAULT_TILE_G = 128


def _join_kernel(meta_ref, pol_ref, pmask_ref, src_ref, dst_ref, emask_ref,
                 matched_ref, count_ref):
    c = pl.program_id(0)
    stub = meta_ref[c, 1]
    to = meta_ref[c, 2]
    fwd = meta_ref[c, 3]

    pol = pol_ref[0]          # (TG, M, K) int32
    pmask = pmask_ref[0]      # (TG, M) int8
    src = src_ref[0]          # (TG, F) int32
    dst = dst_ref[0]          # (TG, F) int32
    emask = emask_ref[0]      # (TG, F) int8

    tg, m, k = pol.shape

    kids = jax.lax.broadcasted_iota(jnp.int32, (tg, m, k), 2)
    stub_vals = jnp.sum(jnp.where(kids == stub, pol, 0), axis=-1)   # (TG, M)
    to_vals = jnp.sum(jnp.where(kids == to, pol, 0), axis=-1)       # (TG, M)

    hit = (src[:, None, :] == stub_vals[:, :, None])                # (TG,M,F)
    hit &= (pmask[:, :, None] != 0) & (emask[:, None, :] != 0)

    # forward: new endpoint must not be a parent vertex (K-step loop keeps
    # the accumulator at (TG, M, F) instead of (TG, M, F, K)).
    def body(kk, acc):
        col = jax.lax.dynamic_index_in_dim(pol, kk, axis=2, keepdims=False)
        return acc | (dst[:, None, :] == col[:, :, None])

    member = jax.lax.fori_loop(
        0, k, body, jnp.zeros((tg, m, f_dim(src)), jnp.bool_))
    bwd_ok = dst[:, None, :] == to_vals[:, :, None]
    ok = hit & jnp.where(fwd == 1, ~member, bwd_ok)                 # (TG,M,F)

    matched_ref[0] = ok.any(axis=(1, 2)).astype(jnp.int32)
    count_ref[0] = ok.sum(axis=(1, 2), dtype=jnp.int32)


def f_dim(src):
    return src.shape[-1]


@functools.partial(jax.jit, static_argnames=("tile_g", "interpret"))
def embedding_join_pallas(
    meta: jnp.ndarray,    # (C, 5) int32
    pol: jnp.ndarray,     # (P, G, M, K) int32
    pmask: jnp.ndarray,   # (P, G, M) int8/bool
    src: jnp.ndarray,     # (T, G, F) int32
    dst: jnp.ndarray,     # (T, G, F) int32
    emask: jnp.ndarray,   # (T, G, F) int8/bool
    *,
    tile_g: int = DEFAULT_TILE_G,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused level join.  G must be a multiple of ``tile_g`` (ops.py pads)."""
    C = meta.shape[0]
    P, G, M, K = pol.shape
    T, _, F = src.shape
    if G % tile_g:
        raise ValueError(f"G={G} not a multiple of tile_g={tile_g}")
    n_g = G // tile_g

    pmask = pmask.astype(jnp.int8)
    emask = emask.astype(jnp.int8)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, n_g),
        in_specs=[
            pl.BlockSpec((1, tile_g, M, K),
                         lambda c, g, meta: (meta[c, 0], g, 0, 0)),
            pl.BlockSpec((1, tile_g, M),
                         lambda c, g, meta: (meta[c, 0], g, 0)),
            pl.BlockSpec((1, tile_g, F),
                         lambda c, g, meta: (meta[c, 4], g, 0)),
            pl.BlockSpec((1, tile_g, F),
                         lambda c, g, meta: (meta[c, 4], g, 0)),
            pl.BlockSpec((1, tile_g, F),
                         lambda c, g, meta: (meta[c, 4], g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_g), lambda c, g, meta: (c, g)),
            pl.BlockSpec((1, tile_g), lambda c, g, meta: (c, g)),
        ],
    )
    matched, count = pl.pallas_call(
        _join_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, G), jnp.int32),
            jax.ShapeDtypeStruct((C, G), jnp.int32),
        ],
        interpret=interpret,
    )(meta, pol, pmask, src, dst, emask)
    return matched, count
