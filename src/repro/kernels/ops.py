"""Jit'd dispatch wrappers around the mining kernels.

``backend`` selection:
  "ref"             pure-jnp (XLA) — default on CPU, also the test oracle
  "fused"           single-launch fused Pallas map phase — production TPU
                    path (join + per-candidate reduction in one kernel,
                    parent-grouped candidate schedule; DESIGN.md §5-6)
  "fused_interpret" the fused kernel in interpret mode — CPU validation
  "pallas"          legacy two-launch Pallas pipeline (join kernel, (C,G)
                    HBM intermediates, then reduce kernel) — kept as the
                    on-device oracle/fallback for the fused path
  "interpret"       the two-launch pipeline in interpret mode

The wrappers own the padding contract: G is padded to the graph tile and
C to the candidate tile with masked-off rows, so kernel callers never see
alignment requirements.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .embedding_join import DEFAULT_TILE_G, embedding_join_pallas
from .fused_level import DEFAULT_TILE_C, fused_level_pallas
from .ref import embedding_join_ref, support_count_ref
from .support_count import support_count_pallas

Backend = Literal["ref", "pallas", "interpret", "fused", "fused_interpret"]

__all__ = ["level_supports", "fused_level_supports", "device_local_supports",
           "default_backend", "is_fused_backend"]


def default_backend() -> Backend:
    return "fused" if jax.default_backend() == "tpu" else "ref"


def is_fused_backend(backend: Backend | None) -> bool:
    return (backend or default_backend()) in ("fused", "fused_interpret")


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def fused_level_supports(
    sched_meta: jnp.ndarray,   # (Cs, 6) int32 — schedule_candidates output
    tiles: jnp.ndarray,        # (NT, 2) int32 block descriptors
    pol: jnp.ndarray,          # (PP, P, G, M, K) int32
    pmask: jnp.ndarray,        # (PP, P, G, M) bool/int8
    src: jnp.ndarray,          # (PP, T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    tile_g: int = DEFAULT_TILE_G,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(partition, scheduled-candidate) (support, embed_count) in ONE
    kernel launch covering every device-local partition.

    Outputs are in scheduled order — gather with ``schedule.inv`` for
    canonical order.  Owns graph-axis padding (padded graphs carry zero
    masks, contributing nothing).
    """
    G = pol.shape[2]
    tg = min(tile_g, _round_up(G, 8))
    polp = _pad_to(pol, 2, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 2, tg)
    srcp = _pad_to(src, 2, tg, value=-1)
    dstp = _pad_to(dst, 2, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 2, tg)
    return fused_level_pallas(sched_meta, tiles, polp, pmaskp, srcp, dstp,
                              emaskp, tile_g=tg, interpret=interpret)


def device_local_supports(
    meta: jnp.ndarray,     # (C, 5) int32 — replicated candidate metadata
    pol: jnp.ndarray,      # (PP, P, G, M, K) — device-local partitions
    pmask: jnp.ndarray,    # (PP, P, G, M)
    src: jnp.ndarray,      # (PP, T, G, F)
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    backend: Backend | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Map phase on one device: the per-candidate join vmapped over the
    device-local partition stack.  Returns the summed (C,) local support
    and embed count plus the per-partition (PP, C) embed counts (the
    straggler-rebalance cost signal).  Non-fused backends only — the
    fused kernel covers the partition axis in its own grid
    (``fused_level_supports``)."""
    sup_pp, emb_pp = jax.vmap(
        lambda a, b, c, d, e: level_supports(
            meta, a, b, c, d, e, backend=backend)
    )(pol, pmask, src, dst, emask)
    return sup_pp.sum(0), emb_pp.sum(0), emb_pp


def level_supports(
    meta: jnp.ndarray,     # (C, 5) int32
    pol: jnp.ndarray,      # (P, G, M, K) int32
    pmask: jnp.ndarray,    # (P, G, M) bool/int8
    src: jnp.ndarray,      # (T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    backend: Backend | None = None,
    tile_g: int = DEFAULT_TILE_G,
    tile_c: int = DEFAULT_TILE_C,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-candidate (local_support, embed_count) for one level.

    This is the whole map-phase compute of a MIRAGE iteration on one
    partition: join + reduce, fused across all candidates.  The fused
    backends build the parent-grouped schedule host-side, so ``meta``
    must be concrete (not a tracer) for them — the distributed driver
    (`core/mapreduce.py`) schedules once per level and calls
    ``fused_level_supports`` directly instead.
    """
    backend = backend or default_backend()
    C = meta.shape[0]
    G = pol.shape[1]

    if backend == "ref":
        matched, count = embedding_join_ref(meta, pol, pmask, src, dst, emask)
        return support_count_ref(matched, count)

    if backend in ("fused", "fused_interpret"):
        from ..core.candgen import schedule_candidates
        sched = schedule_candidates(np.asarray(meta), tile_c)
        sup, emb = fused_level_supports(
            jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
            pol[None], pmask[None], src[None], dst[None], emask[None],
            tile_g=tile_g, interpret=(backend == "fused_interpret"))
        inv = jnp.asarray(sched.inv)
        return jnp.take(sup[0], inv), jnp.take(emb[0], inv)

    interpret = backend == "interpret"
    # pad graphs axis; padded graphs carry zero masks -> no contribution
    tg = min(tile_g, _round_up(G, 8))
    polp = _pad_to(pol, 1, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 1, tg)
    srcp = _pad_to(src, 1, tg, value=-1)
    dstp = _pad_to(dst, 1, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 1, tg)

    matched, count = embedding_join_pallas(
        meta, polp, pmaskp, srcp, dstp, emaskp,
        tile_g=tg, interpret=interpret)

    tc = min(tile_c, C) or 1
    matchedp = _pad_to(matched, 0, tc)
    countp = _pad_to(count, 0, tc)
    sup, emb = support_count_pallas(matchedp, countp, tile_c=tc,
                                    tile_g=tg, interpret=interpret)
    return sup[:C], emb[:C]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
