"""Jit'd dispatch wrappers around the mining kernels.

``backend`` selection:
  "ref"       pure-jnp (XLA) — default on CPU, also the test oracle
  "pallas"    compiled Pallas TPU kernels — production TPU path
  "interpret" Pallas kernels in interpret mode — CPU validation of the
              exact kernel bodies (slow; tests only)

The wrapper owns the padding contract: G is padded to the graph tile and
C to the candidate tile with masked-off rows, so kernel callers never see
alignment requirements.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .embedding_join import DEFAULT_TILE_G, embedding_join_pallas
from .ref import embedding_join_ref, support_count_ref
from .support_count import support_count_pallas

Backend = Literal["ref", "pallas", "interpret"]

__all__ = ["level_supports", "default_backend"]


def default_backend() -> Backend:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def level_supports(
    meta: jnp.ndarray,     # (C, 5) int32
    pol: jnp.ndarray,      # (P, G, M, K) int32
    pmask: jnp.ndarray,    # (P, G, M) bool/int8
    src: jnp.ndarray,      # (T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    backend: Backend | None = None,
    tile_g: int = DEFAULT_TILE_G,
    tile_c: int = 8,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-candidate (local_support, embed_count) for one level.

    This is the whole map-phase compute of a MIRAGE iteration on one
    partition: join + reduce, fused across all candidates.
    """
    backend = backend or default_backend()
    C = meta.shape[0]
    G = pol.shape[1]

    if backend == "ref":
        matched, count = embedding_join_ref(meta, pol, pmask, src, dst, emask)
        return support_count_ref(matched, count)

    interpret = backend == "interpret"
    # pad graphs axis; padded graphs carry zero masks -> no contribution
    tg = min(tile_g, _round_up(G, 8))
    polp = _pad_to(pol, 1, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 1, tg)
    srcp = _pad_to(src, 1, tg, value=-1)
    dstp = _pad_to(dst, 1, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 1, tg)

    matched, count = embedding_join_pallas(
        meta, polp, pmaskp, srcp, dstp, emaskp,
        tile_g=tg, interpret=interpret)

    tc = min(tile_c, C) or 1
    matchedp = _pad_to(matched, 0, tc)
    countp = _pad_to(count, 0, tc)
    sup, emb = support_count_pallas(matchedp, countp, tile_c=tc,
                                    tile_g=tg, interpret=interpret)
    return sup[:C], emb[:C]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
