"""Jit'd dispatch wrappers around the mining kernels.

``backend`` selection:
  "ref"             pure-jnp (XLA) — default on CPU, also the test oracle
  "fused"           single-launch fused Pallas map phase — production TPU
                    path (join + per-candidate reduction in one kernel,
                    parent-grouped candidate schedule; DESIGN.md §5-6)
  "fused_interpret" the fused kernel in interpret mode — CPU validation
  "fused_packed"    the fused kernel with bit-packed verdict bitsets —
                    the per-graph accumulator is ceil(G/32) uint32 words
                    in VMEM and support counting is AND+popcount
                    (DESIGN.md §12); bit-identical to "fused"
  "fused_packed_interpret"  the packed kernel in interpret mode
  "pallas"          legacy two-launch Pallas pipeline (join kernel, (C,G)
                    HBM intermediates, then reduce kernel) — kept as the
                    on-device oracle/fallback for the fused path
  "interpret"       the two-launch pipeline in interpret mode

The wrappers own the padding contract: G is padded to the graph tile and
C to the candidate tile with masked-off rows, so kernel callers never see
alignment requirements.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .bitset import WORD, n_words, tail_mask
from .embedding_join import DEFAULT_TILE_G, embedding_join_pallas
from .fused_level import (DEFAULT_TILE_C, fused_level_packed_pallas,
                          fused_level_pallas)
from .ref import embedding_join_ref, support_count_ref
from .support_count import support_count_pallas

Backend = Literal["ref", "pallas", "interpret", "fused", "fused_interpret",
                  "fused_packed", "fused_packed_interpret"]

__all__ = ["level_supports", "fused_level_supports",
           "fused_level_supports_packed", "device_local_supports",
           "default_backend", "is_fused_backend", "is_packed_backend"]


def default_backend() -> Backend:
    return "fused" if jax.default_backend() == "tpu" else "ref"


def is_fused_backend(backend: Backend | None) -> bool:
    return (backend or default_backend()) in (
        "fused", "fused_interpret", "fused_packed", "fused_packed_interpret")


def is_packed_backend(backend: Backend | None) -> bool:
    return (backend or default_backend()) in (
        "fused_packed", "fused_packed_interpret")


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def fused_level_supports(
    sched_meta: jnp.ndarray,   # (Cs, 6) int32 — schedule_candidates output
    tiles: jnp.ndarray,        # (NT, 2) int32 block descriptors
    pol: jnp.ndarray,          # (PP, P, G, M, K) int32
    pmask: jnp.ndarray,        # (PP, P, G, M) bool/int8
    src: jnp.ndarray,          # (PP, T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    tile_g: int = DEFAULT_TILE_G,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(partition, scheduled-candidate) (support, embed_count) in ONE
    kernel launch covering every device-local partition.

    Outputs are in scheduled order — gather with ``schedule.inv`` for
    canonical order.  Owns graph-axis padding (padded graphs carry zero
    masks, contributing nothing).
    """
    G = pol.shape[2]
    tg = min(tile_g, _round_up(G, 8))
    polp = _pad_to(pol, 2, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 2, tg)
    srcp = _pad_to(src, 2, tg, value=-1)
    dstp = _pad_to(dst, 2, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 2, tg)
    return fused_level_pallas(sched_meta, tiles, polp, pmaskp, srcp, dstp,
                              emaskp, tile_g=tg, interpret=interpret)


def fused_level_supports_packed(
    sched_meta: jnp.ndarray,   # (Cs, 6) int32 — schedule_candidates output
    tiles: jnp.ndarray,        # (NT, 2) int32 block descriptors
    pol: jnp.ndarray,          # (PP, P, G, M, K) int32
    pmask: jnp.ndarray,        # (PP, P, G, M) bool/int8
    src: jnp.ndarray,          # (PP, T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    tile_g: int = DEFAULT_TILE_G,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Packed twin of :func:`fused_level_supports` (DESIGN.md §12).

    Owns the 32-aligned graph-axis padding and builds the valid-graph
    bit mask: tile_g rounds to a multiple of 32 so graph tiles pack to
    whole uint32 words, and ``gmask`` zeroes the ragged padded-G tail
    (padded graphs also carry zero masks — the lane-AND is the second
    line of defence that makes the bitset contract local).  Returns
    ``(sup, emb, vbits)`` in scheduled order; ``vbits`` is the
    per-candidate per-graph verdict bitset, ``(PP, Cs, ceil(G/32))``
    uint32 with the pad-bit tail zero.
    """
    G = pol.shape[2]
    tg = min(_round_up(tile_g, WORD), _round_up(G, WORD))
    polp = _pad_to(pol, 2, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 2, tg)
    srcp = _pad_to(src, 2, tg, value=-1)
    dstp = _pad_to(dst, 2, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 2, tg)
    Gp = polp.shape[2]
    gmask = jnp.asarray(tail_mask(G, words=n_words(Gp)))
    return fused_level_packed_pallas(sched_meta, tiles, gmask, polp, pmaskp,
                                     srcp, dstp, emaskp, tile_g=tg,
                                     interpret=interpret)


def device_local_supports(
    meta: jnp.ndarray,     # (C, 5) int32 — replicated candidate metadata
    pol: jnp.ndarray,      # (PP, P, G, M, K) — device-local partitions
    pmask: jnp.ndarray,    # (PP, P, G, M)
    src: jnp.ndarray,      # (PP, T, G, F)
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    backend: Backend | None = None,
    packed: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Map phase on one device: the per-candidate join vmapped over the
    device-local partition stack.  Returns the summed (C,) local support
    and embed count plus the per-partition (PP, C) embed counts (the
    straggler-rebalance cost signal).  Non-fused backends only — the
    fused kernel covers the partition axis in its own grid
    (``fused_level_supports``).

    ``packed=True`` routes the "ref" backend through the bitset-shaped
    oracle (``embedding.support_bits_ref``: per-graph verdicts pack to
    uint32 words, support = AND+popcount) — bit-identical by
    construction, so the packed pipeline stays exercised on CPU where
    the default backend is "ref".  The two-launch Pallas backends stay
    dense (they are the oracle for the fused path)."""
    if packed and (backend or default_backend()) == "ref":
        from ..core.embedding import support_bits_ref

        sup_pp, emb_pp = jax.vmap(
            lambda a, b, c, d, e: support_bits_ref(
                meta, a, b, c, d, e)[:2]
        )(pol, pmask, src, dst, emask)
        return sup_pp.sum(0), emb_pp.sum(0), emb_pp
    sup_pp, emb_pp = jax.vmap(
        lambda a, b, c, d, e: level_supports(
            meta, a, b, c, d, e, backend=backend)
    )(pol, pmask, src, dst, emask)
    return sup_pp.sum(0), emb_pp.sum(0), emb_pp


def level_supports(
    meta: jnp.ndarray,     # (C, 5) int32
    pol: jnp.ndarray,      # (P, G, M, K) int32
    pmask: jnp.ndarray,    # (P, G, M) bool/int8
    src: jnp.ndarray,      # (T, G, F) int32
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    backend: Backend | None = None,
    tile_g: int = DEFAULT_TILE_G,
    tile_c: int = DEFAULT_TILE_C,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-candidate (local_support, embed_count) for one level.

    This is the whole map-phase compute of a MIRAGE iteration on one
    partition: join + reduce, fused across all candidates.  The fused
    backends build the parent-grouped schedule host-side, so ``meta``
    must be concrete (not a tracer) for them — the distributed driver
    (`core/mapreduce.py`) schedules once per level and calls
    ``fused_level_supports`` directly instead.
    """
    backend = backend or default_backend()
    C = meta.shape[0]
    G = pol.shape[1]

    if backend == "ref":
        matched, count = embedding_join_ref(meta, pol, pmask, src, dst, emask)
        return support_count_ref(matched, count)

    if is_fused_backend(backend):
        from ..core.candgen import schedule_candidates
        sched = schedule_candidates(np.asarray(meta), tile_c)
        interpret = backend.endswith("interpret")
        if is_packed_backend(backend):
            sup, emb, _ = fused_level_supports_packed(
                jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
                pol[None], pmask[None], src[None], dst[None], emask[None],
                tile_g=tile_g, interpret=interpret)
        else:
            sup, emb = fused_level_supports(
                jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
                pol[None], pmask[None], src[None], dst[None], emask[None],
                tile_g=tile_g, interpret=interpret)
        inv = jnp.asarray(sched.inv)
        return jnp.take(sup[0], inv), jnp.take(emb[0], inv)

    interpret = backend == "interpret"
    # pad graphs axis; padded graphs carry zero masks -> no contribution
    tg = min(tile_g, _round_up(G, 8))
    polp = _pad_to(pol, 1, tg, value=-1)
    pmaskp = _pad_to(pmask.astype(jnp.int8), 1, tg)
    srcp = _pad_to(src, 1, tg, value=-1)
    dstp = _pad_to(dst, 1, tg, value=-1)
    emaskp = _pad_to(emask.astype(jnp.int8), 1, tg)

    matched, count = embedding_join_pallas(
        meta, polp, pmaskp, srcp, dstp, emaskp,
        tile_g=tg, interpret=interpret)

    tc = min(tile_c, C) or 1
    matchedp = _pad_to(matched, 0, tc)
    countp = _pad_to(count, 0, tc)
    sup, emb = support_count_pallas(matchedp, countp, tile_c=tc,
                                    tile_g=tg, interpret=interpret)
    return sup[:C], emb[:C]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
