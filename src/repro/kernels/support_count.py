"""Pallas TPU kernel: per-candidate support reduction.

LEGACY TWO-LAUNCH PATH — second launch of the oracle/fallback pipeline
(`backend="pallas"`); the production path is ``fused_level.py``, which
never materializes this kernel's (C, G) inputs (DESIGN.md §6).

Reduces the join kernel's per-graph outputs to per-candidate scalars:

  support[c] = sum_g matched[c, g]      (# graphs containing the child)
  embeds[c]  = sum_g count[c, g]        (total join pairs — cost signal)

The grid is (C/TC, G/TG) with the G axis *innermost*, so each output
block (TC,) is revisited across the G sweep and accumulated in place —
the canonical Pallas revisited-output reduction.  The G tile is the same
as the join kernel's so the two launches stream identically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["support_count_pallas"]


def _reduce_kernel(matched_ref, count_ref, sup_ref, emb_ref):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        sup_ref[...] = jnp.zeros_like(sup_ref)
        emb_ref[...] = jnp.zeros_like(emb_ref)

    sup_ref[...] += jnp.sum(matched_ref[...], axis=1, dtype=jnp.int32)
    emb_ref[...] += jnp.sum(count_ref[...], axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_c", "tile_g", "interpret"))
def support_count_pallas(
    matched: jnp.ndarray,   # (C, G) int32
    count: jnp.ndarray,     # (C, G) int32
    *,
    tile_c: int = 8,
    tile_g: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    C, G = matched.shape
    if C % tile_c or G % tile_g:
        raise ValueError(f"(C={C}, G={G}) not multiples of ({tile_c},{tile_g})")
    grid = (C // tile_c, G // tile_g)
    sup, emb = pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_c, tile_g), lambda c, g: (c, g)),
            pl.BlockSpec((tile_c, tile_g), lambda c, g: (c, g)),
        ],
        out_specs=[
            pl.BlockSpec((tile_c,), lambda c, g: (c,)),
            pl.BlockSpec((tile_c,), lambda c, g: (c,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C,), jnp.int32),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        interpret=interpret,
    )(matched, count)
    return sup, emb
