"""Pallas TPU kernel: single-launch fused map phase (join + support).

One ``pallas_call`` covers the whole map-phase compute of a MIRAGE level
on one device — join *and* per-candidate reduction — replacing the seed
two-launch pipeline (``embedding_join`` then ``support_count``) that
round-tripped two full ``(C, G)`` int32 tensors through HBM between
launches.  See DESIGN.md §5-6 for the traffic argument.

Grid: ``(PP, NT, G/TG)`` with the graph axis innermost.  ``PP`` is the
device-local partition count, ``NT`` the candidate-*tile* count.  Each
grid step loads one graph tile of one partition and joins it against a
block of ``TC = tile_c`` candidates; the per-candidate ``(1, TC)`` output
block is revisited across the G sweep and accumulated in place (the
canonical Pallas revisited-output reduction), so per-graph intermediates
never leave VMEM.

Feeding contract (``core/candgen.schedule_candidates``): candidates are
parent-grouped — every TC-row block shares one ``(parent, triple)`` pair,
recorded in the scalar-prefetched block-descriptor table ``tiles``.  The
data-dependent BlockSpec index maps stream the block's shared parent-OL
and edge-OL tiles from HBM **once per block** instead of once per
candidate (the seed kernel's grid was per-candidate).  Padded rows carry
``valid=0`` in meta column 5 and contribute zero.

Shapes (one device):
  sched_meta (Cs, 6) int32  [parent, stub, to, fwd, triple, valid]
  tiles      (NT, 2) int32  [parent, triple] per candidate block
  pol        (PP, P, G, M, K) int32   stacked parent OLs, PAD = -1
  pmask      (PP, P, G, M)    int8    embedding validity
  src/dst    (PP, T, G, F)    int32   edge-OL endpoints
  emask      (PP, T, G, F)    int8

Outputs (scheduled candidate order — gather with ``schedule.inv`` to
restore canonical order):
  sup (PP, Cs) int32 — per-partition local support
  emb (PP, Cs) int32 — per-partition embedding count (cost signal)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitset import WORD, popcount

__all__ = ["fused_level_pallas", "fused_level_packed_pallas",
           "DEFAULT_TILE_C"]

DEFAULT_TILE_C = 8


def _joined_blocks(meta_ref, ct, tile_c, pol, pmask, src, dst, emask):
    """Yield ``(ok, valid)`` per candidate row of one schedule tile.

    ``ok`` is the (TG, M, F) join-match mask for candidate row
    ``ct * tile_c + i``; ``valid`` its meta valid flag (int32 scalar).
    Shared by the dense and packed kernels so the join semantics cannot
    diverge between the two backends.
    """
    tg, m, k = pol.shape
    f = src.shape[-1]

    kids = jax.lax.broadcasted_iota(jnp.int32, (tg, m, k), 2)
    pair_ok = (pmask[:, :, None] != 0) & (emask[:, None, :] != 0)

    # forward-edge membership test (new endpoint must not be a parent
    # vertex) depends only on (pol, dst) — computed ONCE per block and
    # shared by all tile_c candidates, where the per-candidate grid
    # paid the O(M·F·K) loop per candidate.  Bucket-padded K slots
    # hold PAD (-1) and can never match a real endpoint (ids >= 0).
    def body(kk, acc):
        col = jax.lax.dynamic_index_in_dim(pol, kk, axis=2,
                                           keepdims=False)
        return acc | (dst[:, None, :] == col[:, :, None])

    member = jax.lax.fori_loop(
        0, k, body, jnp.zeros((tg, m, f), jnp.bool_))

    for i in range(tile_c):
        row = ct * tile_c + i
        stub = meta_ref[row, 1]
        to = meta_ref[row, 2]
        fwd = meta_ref[row, 3]
        valid = meta_ref[row, 5]

        stub_vals = jnp.sum(jnp.where(kids == stub, pol, 0),
                            axis=-1)                           # (TG,M)
        to_vals = jnp.sum(jnp.where(kids == to, pol, 0),
                          axis=-1)                             # (TG,M)
        ok = (src[:, None, :] == stub_vals[:, :, None]) & pair_ok
        ok &= jnp.where(fwd == 1, ~member,
                        dst[:, None, :] == to_vals[:, :, None])
        yield ok, valid


def _fused_kernel(meta_ref, tiles_ref, pol_ref, pmask_ref, src_ref, dst_ref,
                  emask_ref, sup_ref, emb_ref, *, tile_c):
    ct = pl.program_id(1)
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        sup_ref[...] = jnp.zeros_like(sup_ref)
        emb_ref[...] = jnp.zeros_like(emb_ref)

    # Shape bucketing pads the schedule with whole valid=0 tiles
    # (descriptor (0, 0)); their output blocks stay at the init zeros,
    # so the entire join is skipped, not just masked — the bucket tail
    # costs HBM streaming of one (already-resident) tile index, no VPU.
    tile_valid = meta_ref[ct * tile_c, 5]
    for i in range(1, tile_c):   # static unroll — TC is a compile constant
        tile_valid = tile_valid | meta_ref[ct * tile_c + i, 5]

    @pl.when(tile_valid != 0)
    def _compute():
        pol = pol_ref[0, 0]      # (TG, M, K) int32 — block's shared parent
        pmask = pmask_ref[0, 0]  # (TG, M) int8
        src = src_ref[0, 0]      # (TG, F) int32 — block's shared triple
        dst = dst_ref[0, 0]      # (TG, F) int32
        emask = emask_ref[0, 0]  # (TG, F) int8

        sups, embs = [], []
        for ok, valid in _joined_blocks(meta_ref, ct, tile_c, pol, pmask,
                                        src, dst, emask):
            sups.append(jnp.sum(ok.any(axis=(1, 2)).astype(jnp.int32))
                        * valid)
            embs.append(ok.sum(dtype=jnp.int32) * valid)

        sup_ref[0] += jnp.stack(sups)
        emb_ref[0] += jnp.stack(embs)


def _fused_packed_kernel(meta_ref, tiles_ref, gmask_ref, pol_ref, pmask_ref,
                         src_ref, dst_ref, emask_ref, sup_ref, emb_ref,
                         vbits_ref, *, tile_c):
    """Packed twin of ``_fused_kernel`` (DESIGN.md §12).

    The per-graph verdict accumulator is a ``ceil(TG/32)``-word uint32
    bitset in VMEM: each candidate's (TG,) any-match vector packs to
    words, lane-ANDs with the valid-graph mask ``gmask`` (ragged G%32
    tail + partition padding), and local support is popcount per
    ``tile_c`` block.  The packed verdict words are also written out
    (``vbits``) so downstream consumers get bitset-shaped support masks
    without re-deriving them.
    """
    ct = pl.program_id(1)
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        sup_ref[...] = jnp.zeros_like(sup_ref)
        emb_ref[...] = jnp.zeros_like(emb_ref)

    # Unlike sup/emb, each vbits block is visited exactly once per
    # (pp, ct, g) step — zero it unconditionally so tiles skipped by the
    # all-invalid fast path below don't leak whatever HBM held before.
    vbits_ref[...] = jnp.zeros_like(vbits_ref)

    tile_valid = meta_ref[ct * tile_c, 5]
    for i in range(1, tile_c):
        tile_valid = tile_valid | meta_ref[ct * tile_c + i, 5]

    @pl.when(tile_valid != 0)
    def _compute():
        pol = pol_ref[0, 0]      # (TG, M, K) int32
        pmask = pmask_ref[0, 0]  # (TG, M) int8
        src = src_ref[0, 0]      # (TG, F) int32
        dst = dst_ref[0, 0]      # (TG, F) int32
        emask = emask_ref[0, 0]  # (TG, F) int8
        gmask = gmask_ref[...]   # (TGW,) uint32 — valid-graph bit lanes
        tg = pol.shape[0]
        tgw = tg // WORD

        verdicts, embs = [], []
        for ok, valid in _joined_blocks(meta_ref, ct, tile_c, pol, pmask,
                                        src, dst, emask):
            verdicts.append(ok.any(axis=(1, 2)) & (valid != 0))   # (TG,)
            embs.append(ok.sum(dtype=jnp.int32) * valid)

        bits = jnp.stack(verdicts).reshape(tile_c, tgw, WORD)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (tile_c, tgw, WORD), 2)
        words = jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1,
                        dtype=jnp.uint32)                      # (TC, TGW)
        words = words & gmask[None, :]                         # lane-AND
        sup_ref[0] += jnp.sum(popcount(words), axis=-1)        # popcount
        emb_ref[0] += jnp.stack(embs)
        vbits_ref[0] = words


@functools.partial(jax.jit, static_argnames=("tile_g", "interpret"))
def fused_level_pallas(
    sched_meta: jnp.ndarray,   # (Cs, 6) int32, Cs = NT * tile_c
    tiles: jnp.ndarray,        # (NT, 2) int32
    pol: jnp.ndarray,          # (PP, P, G, M, K) int32
    pmask: jnp.ndarray,        # (PP, P, G, M) int8/bool
    src: jnp.ndarray,          # (PP, T, G, F) int32
    dst: jnp.ndarray,          # (PP, T, G, F) int32
    emask: jnp.ndarray,        # (PP, T, G, F) int8/bool
    *,
    tile_g: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-launch level supports.  G must be a multiple of ``tile_g``
    (ops.py owns the padding contract); ``tile_c`` is implied by the
    schedule (Cs / NT)."""
    Cs = sched_meta.shape[0]
    NT = tiles.shape[0]
    tile_c = Cs // NT
    if Cs != NT * tile_c:
        raise ValueError(f"Cs={Cs} not a multiple of NT={NT}")
    PP, P, G, M, K = pol.shape
    _, T, _, F = src.shape
    if G % tile_g:
        raise ValueError(f"G={G} not a multiple of tile_g={tile_g}")
    n_g = G // tile_g

    pmask = pmask.astype(jnp.int8)
    emask = emask.astype(jnp.int8)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(PP, NT, n_g),
        in_specs=[
            pl.BlockSpec((1, 1, tile_g, M, K),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 0],
                                                         g, 0, 0)),
            pl.BlockSpec((1, 1, tile_g, M),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 0],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_c),
                         lambda pp, ct, g, meta, tiles: (pp, ct)),
            pl.BlockSpec((1, tile_c),
                         lambda pp, ct, g, meta, tiles: (pp, ct)),
        ],
    )
    sup, emb = pl.pallas_call(
        functools.partial(_fused_kernel, tile_c=tile_c),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((PP, Cs), jnp.int32),
            jax.ShapeDtypeStruct((PP, Cs), jnp.int32),
        ],
        interpret=interpret,
    )(sched_meta, tiles, pol, pmask, src, dst, emask)
    return sup, emb


@functools.partial(jax.jit, static_argnames=("tile_g", "interpret"))
def fused_level_packed_pallas(
    sched_meta: jnp.ndarray,   # (Cs, 6) int32, Cs = NT * tile_c
    tiles: jnp.ndarray,        # (NT, 2) int32
    gmask: jnp.ndarray,        # (G/32,) uint32 — valid-graph bit lanes
    pol: jnp.ndarray,          # (PP, P, G, M, K) int32
    pmask: jnp.ndarray,        # (PP, P, G, M) int8/bool
    src: jnp.ndarray,          # (PP, T, G, F) int32
    dst: jnp.ndarray,          # (PP, T, G, F) int32
    emask: jnp.ndarray,        # (PP, T, G, F) int8/bool
    *,
    tile_g: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Packed single-launch level supports (DESIGN.md §12).

    Same grid and feeding contract as :func:`fused_level_pallas`, with
    ``tile_g`` additionally a multiple of 32 so every graph tile packs to
    whole uint32 words.  Returns ``(sup, emb, vbits)`` where
    ``vbits (PP, Cs, G/32) uint32`` carries per-candidate per-graph
    verdict bitsets in scheduled order — ``sup`` is exactly
    ``popcount(vbits)`` summed over words, computed in VMEM.
    """
    Cs = sched_meta.shape[0]
    NT = tiles.shape[0]
    tile_c = Cs // NT
    if Cs != NT * tile_c:
        raise ValueError(f"Cs={Cs} not a multiple of NT={NT}")
    PP, P, G, M, K = pol.shape
    _, T, _, F = src.shape
    if tile_g % WORD:
        raise ValueError(f"tile_g={tile_g} not a multiple of {WORD}")
    if G % tile_g:
        raise ValueError(f"G={G} not a multiple of tile_g={tile_g}")
    n_g = G // tile_g
    tgw = tile_g // WORD
    if gmask.shape != (G // WORD,):
        raise ValueError(f"gmask shape {gmask.shape} != ({G // WORD},)")

    pmask = pmask.astype(jnp.int8)
    emask = emask.astype(jnp.int8)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(PP, NT, n_g),
        in_specs=[
            pl.BlockSpec((tgw,),
                         lambda pp, ct, g, meta, tiles: (g,)),
            pl.BlockSpec((1, 1, tile_g, M, K),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 0],
                                                         g, 0, 0)),
            pl.BlockSpec((1, 1, tile_g, M),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 0],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
            pl.BlockSpec((1, 1, tile_g, F),
                         lambda pp, ct, g, meta, tiles: (pp, tiles[ct, 1],
                                                         g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_c),
                         lambda pp, ct, g, meta, tiles: (pp, ct)),
            pl.BlockSpec((1, tile_c),
                         lambda pp, ct, g, meta, tiles: (pp, ct)),
            pl.BlockSpec((1, tile_c, tgw),
                         lambda pp, ct, g, meta, tiles: (pp, ct, g)),
        ],
    )
    sup, emb, vbits = pl.pallas_call(
        functools.partial(_fused_packed_kernel, tile_c=tile_c),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((PP, Cs), jnp.int32),
            jax.ShapeDtypeStruct((PP, Cs), jnp.int32),
            jax.ShapeDtypeStruct((PP, Cs, G // WORD), jnp.uint32),
        ],
        interpret=interpret,
    )(sched_meta, tiles, gmask, pol, pmask, src, dst, emask)
    return sup, emb, vbits
