"""Model assembly: scan-over-layer-groups for every assigned family.

A model is a list of *groups*; each group is a unit of sub-layers scanned
``repeat`` times with stacked parameters (O(1) HLO size in depth — the
512-device dry-run compiles depend on this).  Units capture each family's
layer pattern:

  dense        [attn, mlp] × L            (granite/minicpm/qwen2.5/qwen2-vl)
  gemma2       [local-attn, mlp, global-attn, mlp] × L/2 (+post-norms,
               softcaps, sliding window)
  moe          [attn|mla, moe] × L (phi3.5) / leading dense layers (deepseek)
  ssm (xlstm)  [mlstm × (e-1), slstm] × L/e
  hybrid       [mamba × e, shared-attn+mlp] × L/e (zamba2: ONE shared
               attention block's weights reused by every unit)
  encdec       whisper: encoder groups (non-causal) + decoder groups with
               cross-attention (see encdec.py)

Caches mirror the group structure: every leaf is stacked (repeat, ...) so
decode scans carry them positionally.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention, init_attention, init_mla, mla
from .common import dense_init, norm_init, rmsnorm, softcap
from .mlp import init_mlp, init_moe, mlp, moe
from .ssm import init_mamba2, init_mamba2_state, mamba2, mamba2_decode
from .xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                    init_slstm_state, mlstm, mlstm_decode, slstm,
                    slstm_decode)

__all__ = ["GroupSpec", "arch_groups", "init_lm", "forward_lm", "init_cache"]


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    unit: tuple[tuple[str, str], ...]   # ((mixer, ffn), ...) per sub-layer
    repeat: int


def arch_groups(cfg) -> list[GroupSpec]:
    fam = cfg.family
    L = cfg.n_layers
    if fam in ("dense", "vlm"):
        if cfg.local_global:
            assert L % 2 == 0
            return [GroupSpec((("attn_local", "mlp"), ("attn", "mlp")),
                              L // 2)]
        return [GroupSpec((("attn", "mlp"),), L)]
    if fam == "moe":
        mixer = "mla" if cfg.mla else "attn"
        groups = []
        if cfg.first_dense:
            groups.append(GroupSpec(((mixer, "mlp"),), cfg.first_dense))
        groups.append(GroupSpec(((mixer, "moe"),), L - cfg.first_dense))
        return groups
    if fam == "ssm":   # xlstm
        if cfg.slstm_every:
            e = cfg.slstm_every
            assert L % e == 0
            unit = tuple(("mlstm", "none") for _ in range(e - 1))
            unit += (("slstm", "none"),)
            return [GroupSpec(unit, L // e)]
        return [GroupSpec((("mlstm", "none"),), L)]
    if fam == "hybrid":  # zamba2
        e = cfg.hybrid_attn_every
        assert e and L % e == 0
        unit = tuple(("mamba", "none") for _ in range(e))
        unit += (("shared_attn", "mlp"),)
        return [GroupSpec(unit, L // e)]
    if fam in ("encdec", "audio"):
        # decoder-side groups (self-attn -> cross-attn -> mlp);
        # the encoder stack is assembled by encdec.py
        return [GroupSpec((("attn", "none"), ("cross_attn", "mlp")), L)]
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_sublayer(cfg, key, mixer: str, ffn: str) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": norm_init(cfg.d_model)}
    if mixer in ("attn", "attn_local"):
        p["attn"] = init_attention(cfg, ks[0])
    elif mixer == "cross_attn":
        p["attn"] = init_attention(cfg, ks[0], cross=True)
    elif mixer == "mla":
        p["attn"] = init_mla(cfg, ks[0])
    elif mixer == "mamba":
        p["mixer"] = init_mamba2(cfg, ks[0])
    elif mixer == "mlstm":
        p["mixer"] = init_mlstm(cfg, ks[0])
    elif mixer == "slstm":
        p["mixer"] = init_slstm(cfg, ks[0])
    elif mixer == "shared_attn":
        pass  # weights live outside the scan (cfg: zamba2)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["ln2"] = norm_init(cfg.d_model)
    if ffn == "mlp":
        p["mlp"] = init_mlp(cfg, ks[1])
    elif ffn == "moe":
        p["moe"] = init_moe(cfg, ks[1])
    if cfg.post_norms:
        p["post_ln1"] = norm_init(cfg.d_model)
        if ffn != "none":
            p["post_ln2"] = norm_init(cfg.d_model)
    return p


def init_lm(cfg, key) -> dict:
    groups = arch_groups(cfg)
    keys = jax.random.split(key, len(groups) + 3)
    params: dict[str, Any] = {
        "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab))
    if cfg.family == "hybrid":
        params["shared_attn"] = init_attention(cfg, keys[2])
    for gi, g in enumerate(groups):
        def init_unit(k):
            uks = jax.random.split(k, len(g.unit))
            return [_init_sublayer(cfg, uk, m, f)
                    for uk, (m, f) in zip(uks, g.unit)]
        gkeys = jax.random.split(jax.random.fold_in(key, 100 + gi), g.repeat)
        params[f"group_{gi}"] = jax.vmap(init_unit)(gkeys)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _sublayer_cache(cfg, mixer: str, batch: int, max_len: int,
                    dtype) -> Optional[dict]:
    dh = cfg.head_dim
    if mixer in ("attn", "attn_local", "shared_attn"):
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv, dh), dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv, dh), dtype)}
    if mixer == "cross_attn":
        F = cfg.encoder_frames or 1
        return {"k": jnp.zeros((batch, F, cfg.n_kv, dh), dtype),
                "v": jnp.zeros((batch, F, cfg.n_kv, dh), dtype)}
    if mixer == "mla":
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
                "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
    if mixer == "mamba":
        return init_mamba2_state(cfg, batch)
    if mixer == "mlstm":
        return init_mlstm_state(cfg, batch)
    if mixer == "slstm":
        return init_slstm_state(cfg, batch)
    return None


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> list:
    """Per-group stacked caches: leaves get a leading (repeat,) dim."""
    out = []
    for g in arch_groups(cfg):
        unit = [_sublayer_cache(cfg, m, batch, max_len, dtype)
                for (m, f) in g.unit]
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (g.repeat,) + x.shape).copy(),
            unit)
        out.append(stacked)
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_sublayer(p, x, cfg, mixer, ffn, *, shared, cache, cache_pos,
                    positions3, encoder_out, make_cache):
    aux = jnp.float32(0)
    h = rmsnorm(p["ln1"], x, eps=cfg.norm_eps,
                zero_centered=cfg.post_norms)
    if mixer in ("attn", "attn_local"):
        y, new_cache = attention(
            p["attn"], h, cfg, layer_local=(mixer == "attn_local"),
            positions3=positions3, cache=cache, cache_pos=cache_pos,
            make_cache=make_cache)
    elif mixer == "cross_attn":
        y, new_cache = attention(
            p["attn"], h, cfg, is_cross=True, cross_inputs=encoder_out,
            cache=cache, cache_pos=cache_pos, make_cache=make_cache)
    elif mixer == "mla":
        y, new_cache = mla(p["attn"], h, cfg, cache=cache,
                           cache_pos=cache_pos, make_cache=make_cache)
    elif mixer == "shared_attn":
        # zamba2: ONE attention block's weights reused by every unit
        # (its kv cache is still per-unit)
        y, new_cache = attention(
            shared["attn"], h, cfg, cache=cache, cache_pos=cache_pos,
            make_cache=make_cache)
    elif mixer in ("mamba", "mlstm", "slstm"):
        full = {"mamba": mamba2, "mlstm": mlstm, "slstm": slstm}[mixer]
        step = {"mamba": mamba2_decode, "mlstm": mlstm_decode,
                "slstm": slstm_decode}[mixer]
        if cache_pos is None:
            if make_cache:   # prefill: full pass + final recurrent state
                y, new_cache = full(p["mixer"], h, cfg, return_state=True)
            else:            # train
                y, new_cache = full(p["mixer"], h, cfg), cache
        else:                # decode
            y, new_cache = step(p["mixer"], h, cache, cfg)
    else:
        raise ValueError(mixer)
    if cfg.post_norms:
        y = rmsnorm(p["post_ln1"], y, eps=cfg.norm_eps, zero_centered=True)
    x = x + y

    if ffn != "none":
        h = rmsnorm(p["ln2"], x, eps=cfg.norm_eps,
                    zero_centered=cfg.post_norms)
        if ffn == "mlp":
            y = mlp(p["mlp"], h, cfg)
        else:
            y, aux = moe(p["moe"], h, cfg)
        if cfg.post_norms:
            y = rmsnorm(p["post_ln2"], y, eps=cfg.norm_eps,
                        zero_centered=True)
        x = x + y
    return x, new_cache, aux


def forward_lm(params, cfg, *, tokens=None, embeds=None, cache=None,
               cache_pos=None, positions3=None, encoder_out=None,
               make_cache=False, last_logit_only=False):
    """Returns (logits, new_cache_list, aux_loss)."""
    from ..runtime.sharding import gather_for_compute, shard_hint
    dt = jnp.dtype(cfg.dtype)
    embed_w = gather_for_compute({"embed": params["embed"]},
                                 cast=dt)["embed"]
    if embeds is None:
        x = embed_w.astype(dt)[tokens]
        if cfg.post_norms:  # gemma-style input scaling
            x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    else:
        x = embeds.astype(dt)
    x = shard_hint(x, "dp", None, None)

    groups = arch_groups(cfg)
    shared = None
    if cfg.family == "hybrid":
        shared = gather_for_compute({"attn": params["shared_attn"]},
                                    cast=dt)

    new_caches = []
    aux_total = jnp.float32(0)
    for gi, g in enumerate(groups):
        gparams = params[f"group_{gi}"]
        gcache = cache[gi] if cache is not None else None

        def unit_body(x, up, uc):
            # ZeRO-3 use-site gather: weights arrive fsdp+tp sharded;
            # gather the fsdp axes HERE (inside the scan body) so one
            # layer's worth of gathered weights is live at a time and
            # matmuls never contract a dp-sharded dim (which would
            # all-reduce the activations instead).
            from ..runtime.sharding import gather_for_compute
            up = gather_for_compute(up, cast=jnp.dtype(cfg.dtype))
            if cfg.seq_parallel:
                # sequence parallelism: the residual stream between
                # blocks is (batch × seq/model) sharded — TP output
                # all-reduces become reduce-scatters and norms/embed
                # compute runs seq-sharded (Korthikanti et al.)
                x = shard_hint(x, "dp", "model", None)
            auxs = jnp.float32(0)
            new_uc = []
            for li, (m, f) in enumerate(g.unit):
                c = uc[li] if uc is not None else None
                x, nc, aux = _apply_sublayer(
                    up[li], x, cfg, m, f, shared=shared, cache=c,
                    cache_pos=cache_pos, positions3=positions3,
                    encoder_out=encoder_out,
                    make_cache=make_cache or cache is not None)
                new_uc.append(nc)
                auxs = auxs + aux
            return x, (new_uc, auxs)

        body = unit_body
        if cfg.remat == "block":
            body = jax.checkpoint(unit_body, static_argnums=())

        if gcache is None:
            scan_body = lambda x, up: body(x, up, None)
            xs = gparams
        else:
            scan_body = lambda x, inp: body(x, inp[0], inp[1])
            xs = (gparams, gcache)

        if g.repeat == 1:
            sq = jax.tree_util.tree_map(lambda a: a[0], xs)
            x, (nc, aux) = scan_body(x, sq)
            nc = jax.tree_util.tree_map(lambda a: a[None], nc)
        else:
            x, (nc, aux) = jax.lax.scan(scan_body, x, xs)
            aux = aux.sum()
        new_caches.append(nc)
        aux_total = aux_total + aux

    if last_logit_only:
        # serving prefill: only the final position's logits are needed —
        # slice BEFORE the head matmul (XLA does not reliably push a
        # post-hoc slice into the (B,S,V) dot; measured §Perf P8)
        x = x[:, -1:]
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps,
                zero_centered=cfg.post_norms)
    if cfg.tie_embeddings:
        head = embed_w.T.astype(dt)
    else:
        head = gather_for_compute(
            {"lm_head": params["lm_head"]}, cast=dt)["lm_head"].astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    # vocab stays TP-sharded through the loss (the CE path is written to
    # respect it — replicated (B,S,V) logits are a multi-GiB/device bug)
    logits = shard_hint(logits, "dp", None, "model")
    logits = softcap(logits, cfg.final_softcap)
    return logits, (new_caches if (cache is not None or make_cache)
                    else None), aux_total
