"""Feed-forward layers: SwiGLU / GELU MLPs and Mixture-of-Experts.

MoE uses GShard-style capacity dispatch by default (`moe_impl="einsum"`):
top-k routing, per-group expert capacity, one-hot dispatch/combine
einsums — the battle-tested auto-shardable TPU formulation (experts on
the "model" axis = expert parallelism; tokens on "data").  An
index-scatter variant (`moe_impl="scatter"`) avoids the O(T·E·C)
dispatch product and is evaluated in §Perf.

Aux losses (load-balance + router z-loss) are returned by the layer and
accumulated through the block scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = ["init_mlp", "mlp", "init_moe", "moe"]


def init_mlp(cfg, key, *, d_ff=None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (cfg.d_model, d_ff)),
         "w_down": dense_init(ks[1], (d_ff, cfg.d_model))}
    if cfg.mlp == "swiglu":
        p["w_gate"] = dense_init(ks[2], (cfg.d_model, d_ff))
    return p


def mlp(params, x, cfg):
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(cfg, key) -> dict:
    E, dff = cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, E), scale=0.02),
        "w_gate": dense_init(ks[1], (E, cfg.d_model, dff)),
        "w_up": dense_init(ks[2], (E, cfg.d_model, dff)),
        "w_down": dense_init(ks[3], (E, dff, cfg.d_model)),
    }
    if cfg.n_shared:
        shared_ff = cfg.n_shared * dff
        sub = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sub[0], (cfg.d_model, shared_ff)),
            "w_up": dense_init(sub[1], (cfg.d_model, shared_ff)),
            "w_down": dense_init(sub[2], (shared_ff, cfg.d_model)),
        }
    return p


def _route(params, x, cfg):
    """Top-k routing. Returns gates (B,S,E) with zeros off the top-k,
    plus aux losses."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)          # (B,S,k)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=probs.dtype)
    gates = (topv[..., None] * onehot).sum(-2)            # (B,S,E)
    # normalize the selected gates (deepseek/mixtral convention)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * p_e
    f = (gates > 0).astype(jnp.float32).mean((0, 1))      # token fraction
    pbar = probs.mean((0, 1))
    aux = cfg.n_experts * jnp.sum(f * pbar)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, probs, aux + 1e-3 * zloss


def _capacity(cfg, S: int) -> int:
    c = int(np.ceil(S * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(c, 4)


def _expert_ffn(params, xe, cfg):
    """xe: (B, E, C, d) -> (B, E, C, d) through each expert's SwiGLU."""
    dt = xe.dtype
    g = jnp.einsum("becd,edf->becf", xe, params["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt))


def moe(params, x, cfg):
    """Returns (y, aux_loss)."""
    B, S, d = x.shape
    dt = x.dtype
    gates, probs, aux = _route(params, x, cfg)            # (B,S,E)
    C = _capacity(cfg, S)
    E = cfg.n_experts

    # position of each token within its expert's buffer (per batch group)
    sel = gates > 0                                       # (B,S,E)
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1   # (B,S,E)
    keep = sel & (pos < C)

    if cfg.moe_impl == "einsum":
        disp = (keep[..., None]
                & (pos[..., None] == jnp.arange(C))).astype(dt)  # (B,S,E,C)
        xe = jnp.einsum("bsd,bsec->becd", x, disp)
        ye = _expert_ffn(params, xe, cfg)
        comb = disp * gates.astype(dt)[..., None]
        y = jnp.einsum("becd,bsec->bsd", ye, comb)
    elif cfg.moe_impl == "scatter":
        buf = jnp.zeros((B, E, C, d), dt)
        be = jnp.broadcast_to(jnp.arange(E), (B, S, E))
        bb = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, E))
        posc = jnp.where(keep, pos, C)  # OOB drop slot
        buf = jnp.pad(buf, ((0, 0), (0, 0), (0, 1), (0, 0)))
        xb = jnp.broadcast_to(x[:, :, None, :], (B, S, E, d))
        buf = buf.at[bb, be, posc].add(jnp.where(keep[..., None], xb, 0))
        ye = _expert_ffn(params, buf[:, :, :C], cfg)
        ye = jnp.pad(ye, ((0, 0), (0, 0), (0, 1), (0, 0)))
        y = (ye[bb, be, posc] * gates.astype(dt)[..., None]
             * keep[..., None]).sum(2)
    else:
        raise ValueError(cfg.moe_impl)

    if cfg.n_shared:
        sh = params["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           sh["w_down"].astype(dt))
    return y, cfg.router_aux_coef * aux
