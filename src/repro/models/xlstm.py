"""xLSTM blocks: mLSTM (matrix memory, parallel/chunked) + sLSTM (scalar
memory, recurrent).

mLSTM trains in a chunked linear-attention form: exponential input gates
and log-sigmoid forget gates become per-step log-decays; within a chunk
the contribution is an attention-like matmul with a cumulative-decay
mask, across chunks a (H, D, D) matrix state is carried by a scan —
linear in S, which is what qualifies xlstm-1.3b for the long_500k cell.

Numerics note (documented deviation): the paper's running max-stabilizer
``m_t`` is omitted (m ≡ 0) so the chunked-parallel and recurrent forms
are *bit-consistent* (verified in tests); the normalizer keeps the
paper's ``max(|q·n|, 1)`` guard.  Intra-chunk decays are computed in log
space, bounded by chunk_len·|log f| + |i|.

sLSTM keeps the paper's scalar-memory recurrence with full stabilizer
(true lax.scan over time — inherently sequential; placed every
``cfg.slstm_every`` layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = ["init_mlstm", "mlstm", "mlstm_decode", "init_mlstm_state",
           "init_slstm", "slstm", "slstm_decode", "init_slstm_state"]


def _mdims(cfg):
    H = cfg.n_heads
    D = cfg.d_model // H
    return H, D


def init_mlstm(cfg, key) -> dict:
    H, D = _mdims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, H, D)),
        "wk": dense_init(ks[1], (cfg.d_model, H, D)),
        "wv": dense_init(ks[2], (cfg.d_model, H, D)),
        "wi": dense_init(ks[3], (cfg.d_model, H), scale=0.02),
        "wf": dense_init(ks[4], (cfg.d_model, H), scale=0.02),
        "f_bias": 3.0 * jnp.ones((H,), jnp.float32),   # open forget gates
        "wo": dense_init(ks[5], (H, D, cfg.d_model)),
        "ogate": dense_init(ks[6], (cfg.d_model, H, D), scale=0.02),
        "norm": {"scale": jnp.ones((H, D), jnp.float32)},
    }


def _mlstm_gates(params, x):
    i = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wi"])
    f = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["wf"])
    f = f + params["f_bias"]
    log_f = -jax.nn.softplus(-f)           # log sigmoid(f)
    return i, log_f


def _headnorm(params, h):
    var = jnp.mean(jnp.square(h.astype(jnp.float32)), -1, keepdims=True)
    return (h * jax.lax.rsqrt(var + 1e-6) * params["norm"]["scale"]
            ).astype(h.dtype)


def mlstm(params, x, cfg, *, return_state: bool = False):
    """Chunked parallel mLSTM. x: (B,S,d) -> (B,S,d) or (y, state)."""
    H, D = _mdims(cfg)
    B, S, _ = x.shape
    dt_ = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt_)) / np.sqrt(D)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt_))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt_))
    i, log_f = _mlstm_gates(params, x)                    # (B,S,H)

    from .ssm import pick_chunk
    chunk = pick_chunk(S, cfg.ssm_chunk or 256)
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    kc = k.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    ic = i.reshape(B, nc, chunk, H)
    fc = log_f.reshape(B, nc, chunk, H)

    fcum = jnp.cumsum(fc, axis=2)                         # (B,nc,l,H)
    last = fcum[:, :, -1:, :]

    # intra-chunk: w_tu = exp(fcum_t - fcum_u + i_u), u <= t
    L = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = (fcum[:, :, :, None, :] - fcum[:, :, None, :, :]
           + ic[:, :, None, :, :])
    seg = jnp.where(L[None, None, :, :, None], seg, -jnp.inf)
    dmat = jnp.exp(seg)                                   # (B,nc,t,u,H)
    att = jnp.einsum("bcthk,bcuhk->bctuh", qc, kc)
    y_intra = jnp.einsum("bctuh,bcuhk->bcthk", att * dmat, vc)
    den_intra = jnp.einsum("bctuh->bcth", att * dmat)

    # inter-chunk states: S_c = sum_u exp(last - fcum_u + i_u) k_u v_u^T
    dstate = jnp.exp(last - fcum + ic)                    # (B,nc,l,H)
    states = jnp.einsum("bcuh,bcuhk,bcuhn->bchkn", dstate, kc, vc)
    nstates = jnp.einsum("bcuh,bcuhk->bchk", dstate, kc)
    cdecay = jnp.exp(last[:, :, 0, :])                    # (B,nc,H)

    def scan_body(carry, inp):
        Sm, Sn = carry
        st, nt, dec = inp
        return ((Sm * dec[:, :, None, None] + st,
                 Sn * dec[:, :, None] + nt),
                (Sm, Sn))                                 # emit PREV state

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    (Sfin, nfin), (prevS, prevN) = jax.lax.scan(
        scan_body, (S0, n0),
        (states.swapaxes(0, 1), nstates.swapaxes(0, 1),
         cdecay.swapaxes(0, 1)))
    prevS = prevS.swapaxes(0, 1)                          # (B,nc,H,D,D)
    prevN = prevN.swapaxes(0, 1)                          # (B,nc,H,D)

    dq = jnp.exp(fcum)                                    # decay to chunk start
    y_off = jnp.einsum("bcthk,bcth,bchkn->bcthn", qc, dq, prevS)
    den_off = jnp.einsum("bcthk,bcth,bchk->bcth", qc, dq, prevN)

    den = jnp.maximum(jnp.abs(den_intra + den_off), 1.0)  # max(|q·n|, 1)
    y = (y_intra + y_off) / den[..., None]
    y = y.reshape(B, S, H, D).astype(dt_)

    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x,
                                  params["ogate"].astype(dt_)))
    y = _headnorm(params, y) * o
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt_))
    if return_state:
        return out, {"S": Sfin, "n": nfin}
    return out


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    H, D = _mdims(cfg)
    return {"S": jnp.zeros((batch, H, D, D), dtype),
            "n": jnp.zeros((batch, H, D), dtype)}


def mlstm_decode(params, x, state, cfg):
    """Recurrent mLSTM step (matches the chunked form exactly).
    x: (B,1,d)."""
    H, D = _mdims(cfg)
    dt_ = x.dtype
    q = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wq"].astype(dt_)) / np.sqrt(D)
    k = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wk"].astype(dt_))
    v = jnp.einsum("bd,dhk->bhk", x[:, 0], params["wv"].astype(dt_))
    i, log_f = _mlstm_gates(params, x)                    # (B,1,H)
    di = jnp.exp(i[:, 0])
    df = jnp.exp(log_f[:, 0])

    S_new = (state["S"] * df[:, :, None, None]
             + jnp.einsum("bhk,bhn->bhkn", k.astype(jnp.float32),
                          v.astype(jnp.float32)) * di[:, :, None, None])
    n_new = (state["n"] * df[:, :, None]
             + k.astype(jnp.float32) * di[:, :, None])
    num = jnp.einsum("bhk,bhkn->bhn", q.astype(jnp.float32), S_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n_new)),
        1.0)
    y = (num / den[:, :, None]).astype(dt_)[:, None]      # (B,1,H,D)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x,
                                  params["ogate"].astype(dt_)))
    y = _headnorm(params, y) * o
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt_))
    return out, {"S": S_new.astype(state["S"].dtype),
                 "n": n_new.astype(state["n"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg, key) -> dict:
    H, D = _mdims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "w_zifo": dense_init(ks[0], (d, 4, H, D)),
        "r_zifo": dense_init(ks[1], (4, H, D, D), scale=0.02),
        "b_zifo": jnp.zeros((4, H, D), jnp.float32),
        "wo": dense_init(ks[2], (H, D, d)),
        "norm": {"scale": jnp.ones((H, D), jnp.float32)},
    }


def init_slstm_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    H, D = _mdims(cfg)
    z = lambda: jnp.zeros((batch, H, D), dtype)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, H, D), -30.0, dtype)}


def _slstm_step(params, xt, st):
    """One sLSTM step (full stabilizer).  xt: (B,4,H,D) pre-projected."""
    h_prev = st["h"]
    rec = jnp.einsum("bhd,ghde->bghe", h_prev.astype(jnp.float32),
                     params["r_zifo"])
    g = xt.astype(jnp.float32) + rec + params["b_zifo"]
    z = jnp.tanh(g[:, 0])
    i = g[:, 1]                       # exponential input gate (log space)
    log_f = -jax.nn.softplus(-g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(log_f + st["m"], i)
    di = jnp.exp(i - m_new)
    df = jnp.exp(log_f + st["m"] - m_new)
    c_new = df * st["c"] + di * z
    n_new = df * st["n"] + di
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm(params, x, cfg, *, return_state: bool = False):
    """Sequential sLSTM over S (lax.scan). x: (B,S,d)."""
    B, S, _ = x.shape
    dt_ = x.dtype
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["w_zifo"].astype(dt_))
    st0 = init_slstm_state(cfg, B)

    def body(st, xt):
        st = _slstm_step(params, xt, st)
        return st, st["h"]

    st_fin, hs = jax.lax.scan(body, st0, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(dt_)                     # (B,S,H,D)
    y = _headnorm(params, y)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt_))
    if return_state:
        return out, st_fin
    return out


def slstm_decode(params, x, state, cfg):
    dt_ = x.dtype
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["w_zifo"].astype(dt_))
    st = _slstm_step(params, xg[:, 0], state)
    y = st["h"].astype(dt_)[:, None]
    y = _headnorm(params, y)
    return (jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(dt_)),
            {k: v.astype(state[k].dtype) for k, v in st.items()})
