"""Architecture registry: ``--arch <id>`` -> config + model functions.

``build(cfg)`` returns the family-appropriate function set:
    init(key) -> params
    loss_fn(params, batch) -> (loss, aux-metrics)      [train_step]
    prefill(params, batch) -> (logits, cache)          [prefill_step]
    decode(params, cache, batch, pos) -> (logits, cache) [decode_step]

Param counts come from ``jax.eval_shape`` over the real initializers —
exact by construction, used for the analytic 6·N·D roofline term.
"""
from __future__ import annotations

import functools
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .encdec import encode, forward_encdec, init_encdec
from .transformer import forward_lm, init_cache, init_lm

ARCHS = [
    "whisper_base", "zamba2_2p7b", "granite_20b", "gemma2_2b", "minicpm_2b",
    "qwen2p5_14b", "deepseek_v2_lite", "phi3p5_moe", "xlstm_1p3b",
    "qwen2_vl_72b",
]

_ALIASES = {
    "whisper-base": "whisper_base", "zamba2-2.7b": "zamba2_2p7b",
    "granite-20b": "granite_20b", "gemma2-2b": "gemma2_2b",
    "minicpm-2b": "minicpm_2b", "qwen2.5-14b": "qwen2p5_14b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe", "xlstm-1.3b": "xlstm_1p3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

__all__ = ["ARCHS", "get_config", "get_smoke_config", "build",
           "count_params", "list_archs"]


def list_archs() -> list[str]:
    return list(ARCHS)


def _module(name: str):
    name = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).smoke_config()


# ---------------------------------------------------------------------------

def count_params(cfg, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape over the real initializer."""
    init = init_encdec if cfg.family in ("encdec", "audio") else init_lm
    shapes = jax.eval_shape(lambda k: init(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "moe" in keys and "shared" not in keys and "router" not in keys:
            expert += n
    if active_only and cfg.n_experts:
        total -= int(expert * (1 - cfg.top_k / cfg.n_experts))
    return total


# ---------------------------------------------------------------------------

def _ce_loss(logits, labels, vocab):
    """CE that respects vocab (TP) sharding: the gold logit is extracted
    with a masked sum, NOT take_along_axis — a gather over the sharded
    vocab dim makes XLA all-gather the full (B,S,V) logits per device."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    mask = labels[..., None] == jnp.arange(vocab)[None, None]
    gold = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    loss = (logz - gold).mean()
    zloss = 1e-4 * jnp.mean(logz ** 2)
    return loss + zloss


def build(cfg) -> dict[str, Callable]:
    fam = cfg.family

    if fam in ("encdec", "audio"):
        def init(key):
            return init_encdec(cfg, key)

        def loss_fn(params, batch):
            logits, _, aux = forward_encdec(
                params, cfg, tokens=batch["tokens"], frames=batch["frames"])
            loss = _ce_loss(logits, batch["labels"], cfg.vocab) + aux
            return loss, {"ce": loss, "aux": aux}

        def prefill(params, batch):
            enc = encode(params, batch["frames"], cfg)
            logits, cache, _ = forward_encdec(
                params, cfg, tokens=batch["tokens"], encoder_out=enc,
                make_cache=True)
            if cfg.prefill_logits == "last":
                logits = logits[:, -1:]
            return logits, cache

        def decode(params, cache, batch, pos):
            logits, cache, _ = forward_encdec(
                params, cfg, tokens=batch["tokens"], cache=cache,
                cache_pos=pos)
            return logits, cache

        return {"init": init, "loss_fn": loss_fn, "prefill": prefill,
                "decode": decode}

    def init(key):
        return init_lm(cfg, key)

    def _inputs(batch):
        kw = {}
        if "embeds" in batch:
            kw["embeds"] = batch["embeds"]
        else:
            kw["tokens"] = batch["tokens"]
        if "positions3" in batch:
            kw["positions3"] = batch["positions3"]
        return kw

    def loss_fn(params, batch):
        logits, _, aux = forward_lm(params, cfg, **_inputs(batch))
        loss = _ce_loss(logits, batch["labels"], cfg.vocab) + aux
        return loss, {"ce": loss, "aux": aux}

    def prefill(params, batch):
        logits, cache, _ = forward_lm(
            params, cfg, **_inputs(batch), make_cache=True,
            last_logit_only=(cfg.prefill_logits == "last"))
        return logits, cache

    def decode(params, cache, batch, pos):
        logits, cache, _ = forward_lm(params, cfg, **_inputs(batch),
                                      cache=cache, cache_pos=pos)
        return logits, cache

    return {"init": init, "loss_fn": loss_fn, "prefill": prefill,
            "decode": decode}
