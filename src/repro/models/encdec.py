"""Encoder-decoder assembly (whisper-base backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed mel-frame embeddings (B, F, d_model); the encoder is
a non-causal transformer over frames, the decoder is the standard
`transformer.py` stack with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, init_attention
from .common import norm_init, rmsnorm
from .mlp import init_mlp, mlp
from .transformer import forward_lm, init_lm

__all__ = ["init_encdec", "forward_encdec", "encode"]


def init_encoder(cfg, key) -> dict:
    def init_layer(k):
        ks = jax.random.split(k, 2)
        return {"ln1": norm_init(cfg.d_model),
                "attn": init_attention(cfg, ks[0]),
                "ln2": norm_init(cfg.d_model),
                "mlp": init_mlp(cfg, ks[1])}
    keys = jax.random.split(key, cfg.encoder_layers)
    return {"layers": jax.vmap(init_layer)(keys),
            "final_norm": norm_init(cfg.d_model)}


def init_encdec(cfg, key) -> dict:
    k1, k2 = jax.random.split(key)
    params = init_lm(cfg, k1)               # decoder + embed + head
    params["encoder"] = init_encoder(cfg, k2)
    return params


def encode(params, frames, cfg):
    """frames: (B, F, d_model) precomputed embeddings -> encoder output."""
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        from ..runtime.sharding import gather_for_compute
        lp = gather_for_compute(lp, cast=jnp.dtype(cfg.dtype))
        h = rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
        # non-causal self-attention over frames
        y, _ = attention(lp["attn"], h, cfg, is_cross=True, cross_inputs=h)
        x = x + y
        h = rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        return x + mlp(lp["mlp"], h, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, eps=cfg.norm_eps)


def forward_encdec(params, cfg, *, tokens, frames=None, encoder_out=None,
                   cache=None, cache_pos=None, make_cache=False):
    """Returns (logits, cache, aux).  For decode, pass ``cache`` built at
    prefill (cross k/v are static inside it) and ``encoder_out=None``."""
    if encoder_out is None and frames is not None:
        encoder_out = encode(params, frames, cfg)
    return forward_lm(params, cfg, tokens=tokens, cache=cache,
                      cache_pos=cache_pos, encoder_out=encoder_out,
                      make_cache=make_cache)
