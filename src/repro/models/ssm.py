"""Mamba2 mixer (SSD) — chunked, MXU-friendly formulation.

The selective-state-space recurrence

    h_t = exp(dt_t · A) · h_{t-1} + dt_t · B_t x_tᵀ ;  y_t = C_t h_t + D x_t

is computed with the Mamba2 "state-space duality" chunked algorithm:
intra-chunk terms become attention-like matmuls (MXU), inter-chunk state
is carried by a scan over chunks of length ``cfg.ssm_chunk`` — linear in
sequence length, which is what qualifies the hybrid/ssm archs for the
``long_500k`` cell.  Decode keeps the recurrent (B·H·P·N) state and is
O(1) per token.

TPU adaptation: the depthwise causal conv1d of the Mamba block is
expressed as k shifted adds (k = d_conv ≤ 4) instead of a conv op —
cheaper to shard and keeps the HLO free of convolution instructions the
roofline parser would otherwise need to model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = ["init_mamba2", "mamba2", "mamba2_decode", "init_mamba2_state"]


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    P = d_inner // H          # head dim
    N = cfg.ssm_state         # state dim
    return d_inner, H, P, N


def init_mamba2(cfg, key) -> dict:
    d_inner, H, P, N = _dims(cfg)
    ks = jax.random.split(key, 6)
    # in_proj packs [z (gate), x, B, C, dt] as in the reference impl
    d_in_proj = 2 * d_inner + 2 * N + H
    return {
        "w_in": dense_init(ks[0], (cfg.d_model, d_in_proj)),
        "conv": dense_init(ks[1], (cfg.d_conv, d_inner + 2 * N),
                           scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, cfg.d_model)),
        "norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
    }


def pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (production shapes divide
    evenly; this is the fallback for odd test lengths)."""
    c = min(chunk, s)
    while s % c:
        c -= 1
    return c


def _split_in(proj, cfg):
    d_inner, H, P, N = _dims(cfg)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, k):
    """Depthwise causal conv1d as k shifted adds. xBC: (B,S,D), w: (k,D)."""
    out = xBC * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out)


def _ssd_chunked(x, dt, A, B, C, chunk):
    """x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,n) (single group broadcast).

    Returns y:(b,s,h,p).  Chunked SSD (Mamba2 paper, 'minimal' listing):
    decay L within chunks -> intra-chunk quadratic term; chunk states
    passed by a scan.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    a = -jnp.exp(A)[None, None, None, :] * dtc           # (b,nc,l,h) log-decay
    a_cum = jnp.cumsum(a, axis=2)

    # intra-chunk: y_intra[t] = sum_{u<=t} C_t·B_u dt_u exp(a_cum_t - a_cum_u) x_u
    L = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]   # (b,nc,t,u,h)
    seg = jnp.where(L[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bctn,bcun->bctu", Cc, Bc)                # (b,nc,t,u)
    y_intra = jnp.einsum("bctu,bctuh,bcuh,bcuhp->bcthp",
                         cb, decay, dtc, xc)

    # chunk states: S_c = sum_u exp(a_cum_last - a_cum_u) dt_u B_u x_u^T
    last = a_cum[:, :, -1:, :]                                # (b,nc,1,h)
    dstate = jnp.exp(last - a_cum)                            # (b,nc,l,h)
    states = jnp.einsum("bcun,bcuh,bcuhp->bchnp", Bc, dstate * dtc, xc)

    # inter-chunk scan: carry running state with chunk-level decay
    chunk_decay = jnp.exp(last[:, :, 0, :])                   # (b,nc,h)

    def scan_body(carry, inp):
        st, dec = inp                                         # (b,h,n,p),(b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                     # emit PREV state

    init = jnp.zeros((b, h, n, p), x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_body, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                  # (b,nc,h,n,p)

    # inter-chunk contribution: y_off[t] = C_t exp(a_cum_t) · prev_state
    y_off = jnp.einsum("bctn,bcth,bchnp->bcthp",
                       Cc, jnp.exp(a_cum), prev_states)
    y = (y_intra + y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2(params, u, cfg, *, return_state: bool = False):
    """Full-sequence mixer. u: (B,S,d_model) -> (B,S,d_model) or
    (y, state) when ``return_state`` (prefill)."""
    from .common import rmsnorm
    d_inner, H, P, N = _dims(cfg)
    dt_ = u.dtype
    proj = jnp.einsum("bsd,de->bse", u, params["w_in"].astype(dt_))
    z, xBC_raw, dt = _split_in(proj, cfg)
    xBC = _causal_conv(xBC_raw, params["conv"].astype(dt_), cfg.d_conv)
    x, B, C = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    b, s, _ = x.shape
    x = x.reshape(b, s, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])                 # (b,s,H)
    y, final = _ssd_chunked(x.astype(jnp.float32), dt, params["A_log"],
                            B.astype(jnp.float32), C.astype(jnp.float32),
                            pick_chunk(s, cfg.ssm_chunk))
    y = y + x.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(dt_)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    if not return_state:
        return out
    k = cfg.d_conv - 1
    conv_state = xBC_raw[:, -k:].astype(jnp.float32) if k else \
        jnp.zeros((b, 0, d_inner + 2 * N), jnp.float32)
    return out, {"ssm": final.astype(jnp.float32), "conv": conv_state}


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, H, P, N = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner + 2 * N), dtype),
    }


def mamba2_decode(params, u, state, cfg):
    """One-token step. u: (B,1,d); state: {"ssm","conv"} -> (y, state)."""
    from .common import rmsnorm
    d_inner, H, P, N = _dims(cfg)
    dt_ = u.dtype
    proj = jnp.einsum("bsd,de->bse", u, params["w_in"].astype(dt_))
    z, xBC, dt = _split_in(proj, cfg)
    # conv over the rolling window
    w = params["conv"].astype(dt_)
    hist = jnp.concatenate([state["conv"], xBC.astype(state["conv"].dtype)],
                           axis=1)                            # (B,k,D)
    xBC = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, w))[:, None, :]
    new_conv = hist[:, 1:]
    x, B, C = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    b = x.shape[0]
    x = x.reshape(b, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(-jnp.exp(params["A_log"])[None] * dt)         # (b,H)
    Bv = B[:, 0].astype(jnp.float32)                          # (b,N)
    Cv = C[:, 0].astype(jnp.float32)
    h = (state["ssm"] * a[:, :, None, None]
         + jnp.einsum("bh,bn,bhp->bhnp", dt, Bv, x))
    y = jnp.einsum("bn,bhnp->bhp", Cv, h) + x * params["D"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(dt_)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    return out, {"ssm": h.astype(state["ssm"].dtype), "conv": new_conv}
