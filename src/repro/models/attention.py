"""Attention layers: GQA (+bias/softcap/sliding-window), MLA, cross-attn.

All full-sequence paths run **online-softmax chunked attention** (Rabe &
Staats) — the (S, T) score matrix is never materialized, which is what
makes the 32k-prefill and 4k-train cells lowerable at production batch
sizes.  Decode paths attend one query over the cache directly.

MLA (deepseek-v2) implements the *compressed-latent cache*: prefill
caches (c_kv, k_rope) only — (kv_lora + rope_dim) per token instead of
2·H·dh — and decode runs the absorbed-matmul form entirely in latent
space.

Cache contract (per layer):
  GQA:  {"k": (B, T, Kv, dh), "v": (B, T, Kv, dh)}
  MLA:  {"ckv": (B, T, kv_lora), "kr": (B, T, rope_dim)}
  cross:{"k": (B, F, Kv, dh), "v": ...}  (computed once from encoder out)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import apply_mrope, apply_rope, dense_init, rope_table, softcap

__all__ = ["init_attention", "attention", "init_mla", "mla",
           "chunked_mha", "plain_mha"]

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Core softmax attention (shared by every variant)
# ---------------------------------------------------------------------------

def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int],
                kv_len: Optional[jnp.ndarray]):
    """(qc, kc) bool mask for a block given absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


def plain_mha(q, k, v, *, scale, causal=False, window=None, cap=None,
              q_offset=0, kv_len=None):
    """Materializing attention — decode / tiny-sequence path.
    q: (B, S, H, D), k/v: (B, T, Kv, Dv)."""
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    qg = q.reshape(B, S, Kv, rep, D)
    s = jnp.einsum("bskrd,btkd->bkrst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    q_pos = q_offset + jnp.arange(S)
    mask = _block_mask(q_pos, jnp.arange(T), causal=causal, window=window,
                       kv_len=kv_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrst,btkd->bskrd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def chunked_mha(q, k, v, *, scale, causal=True, window=None, cap=None,
                q_offset=0, q_chunk=512, kv_chunk=1024, schedule="full"):
    """Online-softmax attention over KV chunks: O(qc·kc) live scores.

    Compiled as ONE outer scan over q blocks × one inner loop over kv
    blocks (O(1) HLO size in sequence length).  ``schedule``:

      "full" — inner scan visits every kv block and masks above-diagonal
               blocks.  2× causal-FLOP overcount, but statically counted
               trip counts (exact roofline attribution).
      "tri"  — inner ``fori_loop`` with dynamic bound (block row index):
               above-diagonal blocks are never computed.  Halves causal
               attention compute; trip count is data-dependent in HLO
               (roofline uses the analytic (nq+1)/2nk factor).
    """
    B, S, H, D = q.shape
    T, Kv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // Kv
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    nq, nk = S // qc, T // kc
    assert S % qc == 0 and T % kc == 0, (S, T, qc, kc)

    qb = q.reshape(B, nq, qc, Kv, rep, D).swapaxes(0, 1)   # (nq,B,qc,Kv,r,D)
    kb = k.reshape(B, nk, kc, Kv, D).swapaxes(0, 1)        # (nk,B,kc,Kv,D)
    vb = v.reshape(B, nk, kc, Kv, Dv).swapaxes(0, 1)

    def kv_step(qi, qblk, q_pos, carry, kj):
        m, l, acc = carry
        kblk = jax.lax.dynamic_index_in_dim(kb, kj, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, kj, 0, keepdims=False)
        k_pos = kj * kc + jnp.arange(kc)
        s = jnp.einsum("bqkrd,btkd->bkrqt", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        msk = _block_mask(q_pos, k_pos, causal=causal, window=window,
                          kv_len=None)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        r = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * r + p.sum(-1)
        acc_new = acc * r[..., None] + jnp.einsum(
            "bkrqt,btkd->bkrqd", p, vblk.astype(jnp.float32))
        return m_new, l_new, acc_new

    # checkpoint each kv block: backward recomputes the (qc, kc) scores
    # blockwise instead of saving every block's residuals (which would
    # materialize the full B·H·S² score tensor across the scan)
    kv_step_ckpt = jax.checkpoint(kv_step, static_argnums=())

    def per_qblock(carry, inp):
        qi, qblk = inp
        q_pos = q_offset + qi * qc + jnp.arange(qc)
        m0 = jnp.full((B, Kv, rep, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, rep, qc), jnp.float32)
        a0 = jnp.zeros((B, Kv, rep, qc, Dv), jnp.float32)
        if schedule == "tri" and causal and window is None and T == S:
            m, l, acc = jax.lax.fori_loop(
                0, qi + 1,
                lambda kj, c: kv_step_ckpt(qi, qblk, q_pos, c, kj),
                (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, kj: (kv_step_ckpt(qi, qblk, q_pos, c, kj), None),
                (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l[..., None], 1e-37)
        return carry, o

    _, o = jax.lax.scan(per_qblock, None, (jnp.arange(nq), qb))
    # (nq, B, Kv, rep, qc, Dv) -> (B, S, H, Dv)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, Dv)
    return o.astype(q.dtype)


def mha(q, k, v, *, scale, causal, window, cap, q_offset=0, kv_len=None,
        q_chunk=512, kv_chunk=1024, schedule="full"):
    """Dispatch: chunked for long sequences, plain for short/decode."""
    S, T = q.shape[1], k.shape[1]
    if S <= q_chunk or S % q_chunk or T % kv_chunk:
        return plain_mha(q, k, v, scale=scale, causal=causal, window=window,
                         cap=cap, q_offset=q_offset, kv_len=kv_len)
    return chunked_mha(q, k, v, scale=scale, causal=causal, window=window,
                       cap=cap, q_offset=q_offset, q_chunk=q_chunk,
                       kv_chunk=kv_chunk, schedule=schedule)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(cfg, key, *, cross: bool = False) -> dict:
    dh = cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, dh)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv, dh)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv, dh)),
        "wo": dense_init(ks[3], (cfg.n_heads, dh, cfg.d_model)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, dh), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv, dh), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv, dh), jnp.float32)
    return p


def attention(params, x, cfg, *, layer_local: bool = False,
              positions=None, positions3=None,
              cache: Optional[dict] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              cross_inputs: Optional[jnp.ndarray] = None,
              is_cross: bool = False,
              make_cache: bool = False):
    """Unified GQA layer.

    Modes:
      train:        cache=None, make_cache=False          -> (y, None)
      prefill:      make_cache=True                       -> (y, cache)
      decode:       cache + cache_pos                     -> (y, new cache)
      cross:        is_cross + (cross_inputs | static cache)
    """
    B, S, _ = x.shape
    dh = cfg.head_dim
    dt = x.dtype
    scale = 1.0 / np.sqrt(dh)
    schedule = getattr(cfg, "attn_schedule", "full")

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)

    if is_cross:
        # encoder-side k/v: no rope, no causal mask
        if cross_inputs is not None:
            k = jnp.einsum("bfd,dhk->bfhk", cross_inputs,
                           params["wk"].astype(dt))
            v = jnp.einsum("bfd,dhk->bfhk", cross_inputs,
                           params["wv"].astype(dt))
            if "bk" in params:
                k, v = k + params["bk"].astype(dt), v + params["bv"].astype(dt)
            new_cache = {"k": k, "v": v} if make_cache else cache
        else:  # decode: static cross cache built at prefill
            k, v = cache["k"], cache["v"]
            new_cache = cache
        o = mha(q, k, v, scale=scale, causal=False, window=None,
                cap=cfg.attn_softcap, schedule=schedule)
        y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
        return y, new_cache

    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = base + jnp.arange(S)[None, :]
        positions = jnp.broadcast_to(positions, (B, S))

    def rope_fn(t):
        if cfg.mrope_sections is not None and positions3 is not None:
            return apply_mrope(t, positions3, dh, cfg.rope_theta,
                               cfg.mrope_sections)
        sin, cos = rope_table(positions, dh, cfg.rope_theta)
        return apply_rope(t, sin, cos)

    q = rope_fn(q)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bk" in params:
        k, v = k + params["bk"].astype(dt), v + params["bv"].astype(dt)
    k = rope_fn(k)

    window = cfg.sliding_window if layer_local else None

    if cache is None:
        o = mha(q, k, v, scale=scale, causal=True, window=window,
                cap=cfg.attn_softcap, schedule=schedule)
        new_cache = {"k": k, "v": v} if make_cache else None
    else:
        # decode: write new k/v at cache_pos, attend over the prefix
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        kv_len = cache_pos + S
        o = plain_mha(q, ck, cv, scale=scale, causal=True, window=window,
                      cap=cfg.attn_softcap, q_offset=cache_pos,
                      kv_len=kv_len)
        new_cache = {"k": ck, "v": cv}

    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): compressed-latent KV cache + absorbed decode
# ---------------------------------------------------------------------------

def init_mla(cfg, key) -> dict:
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "w_dkv": dense_init(ks[0], (cfg.d_model, cfg.kv_lora)),
        "w_kr": dense_init(ks[1], (cfg.d_model, cfg.qk_rope_dim)),
        "w_uk": dense_init(ks[2], (cfg.kv_lora, H, cfg.qk_nope_dim)),
        "w_uv": dense_init(ks[3], (cfg.kv_lora, H, cfg.v_head_dim)),
        "wo": dense_init(ks[4], (H, cfg.v_head_dim, cfg.d_model)),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora,), jnp.float32)},
    }
    if cfg.q_lora:
        p["w_dq"] = dense_init(ks[5], (cfg.d_model, cfg.q_lora))
        p["w_uq"] = dense_init(ks[6], (cfg.q_lora, H, qk))
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora,), jnp.float32)}
    else:
        p["wq"] = dense_init(ks[5], (cfg.d_model, H, qk))
    return p


def mla(params, x, cfg, *, cache=None, cache_pos=None, make_cache=False,
        positions=None):
    from .common import rmsnorm
    B, S, _ = x.shape
    dt = x.dtype
    H = cfg.n_heads
    nope, rdim = cfg.qk_nope_dim, cfg.qk_rope_dim
    scale = 1.0 / np.sqrt(nope + rdim)

    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = base + jnp.arange(S)[None, :]
        positions = jnp.broadcast_to(positions, (B, S))
    sin, cos = rope_table(positions, rdim, cfg.rope_theta)

    if cfg.q_lora:
        cq = rmsnorm(params["q_norm"], jnp.einsum(
            "bsd,dr->bsr", x, params["w_dq"].astype(dt)), eps=cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, sin, cos)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    ckv = rmsnorm(params["kv_norm"], ckv, eps=cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(dt))
    kr = apply_rope(kr[:, :, None, :], sin, cos)[:, :, 0]     # shared head

    if cache is not None and cache_pos is not None:
        # ---- absorbed decode: stay in latent space -------------------
        T = cache["ckv"].shape[1]
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, cache_pos, 0))
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope,
                           params["w_uk"].astype(dt))          # (B,S,H,lora)
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        cc.astype(jnp.float32))
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          ckr.astype(jnp.float32))) * scale
        kv_len = cache_pos + S
        k_pos = jnp.arange(T)
        q_pos = cache_pos + jnp.arange(S)
        msk = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] < kv_len)
        s = jnp.where(msk[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p, cc.astype(jnp.float32))
        o = jnp.einsum("bshr,rhv->bshv", o_lat.astype(dt),
                       params["w_uv"].astype(dt))
        y = jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(dt))
        return y, {"ckv": cc, "kr": ckr}

    # ---- train / prefill: decompress k,v and run chunked attention ----
    k_nope = jnp.einsum("bsr,rhn->bshn", ckv, params["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhv->bshv", ckv, params["w_uv"].astype(dt))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, rdim))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    o = mha(qf, k, v, scale=scale, causal=True, window=None, cap=None)
    y = jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(dt))
    new_cache = {"ckv": ckv, "kr": kr} if make_cache else None
    return y, new_cache
