"""Shared model building blocks (pure JAX — no flax/optax on purpose).

Convention: a layer is a pair of plain functions
    init_<layer>(cfg, key, ...) -> params (nested dict of jnp arrays)
    <layer>(params, x, ...)     -> y
Parameters are stored fp32 and cast to the compute dtype at use
(mixed-precision policy), so the optimizer state stays full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["dense_init", "norm_init", "rmsnorm", "layernorm", "rope_table",
           "apply_rope", "apply_mrope", "softcap", "cdtype", "split_keys"]


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def dense_init(key, shape, *, scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def norm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, *, eps: float = 1e-6, zero_centered: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = params["scale"]
    if zero_centered:  # gemma-style (1 + w)
        scale = 1.0 + scale
    return (y * scale).astype(dt)


def layernorm(params, x, *, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    out = y * params["scale"]
    if "bias" in params:
        out = out + params["bias"]
    return out.astype(dt)


def rope_table(positions: jnp.ndarray, dim: int, theta: float
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(sin, cos) tables for given positions (..., S) -> (..., S, dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (..., S, H, D); sin/cos: (..., S, D/2) broadcast over heads."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    s, c = sin[..., None, :], cos[..., None, :]
    if s.ndim < x1.ndim:  # (S, D/2) -> broadcast batch
        s, c = s[None], c[None]
    # rotate-half convention (llama)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, dim: int,
                theta: float, sections: tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the rotary dim is split into (t, h, w) sections,
    each rotated by its own position stream.  ``positions3``: (3, B, S).
    Text tokens carry identical t/h/w positions (the provided stub path).
    """
    d2 = dim // 2
    sec = np.asarray(sections)
    assert sec.sum() == d2, f"mrope sections {sections} != dim/2 {d2}"
    sins, coss = [], []
    start = 0
    for i, width in enumerate(sec):
        freqs = 1.0 / (theta ** (jnp.arange(start, start + width,
                                            dtype=jnp.float32) * 2.0 / dim))
        ang = positions3[i][..., None].astype(jnp.float32) * freqs
        sins.append(jnp.sin(ang))
        coss.append(jnp.cos(ang))
        start += width
    sin = jnp.concatenate(sins, -1)   # (B, S, d2)
    cos = jnp.concatenate(coss, -1)
    x1, x2 = x[..., :d2], x[..., d2:]
    s, c = sin[:, :, None, :], cos[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
