"""Production mesh construction (assignment-specified shapes).

single pod : (16, 16)    -> ("data", "model")   = 256 chips (TPU v5e pod)
multi-pod  : (2, 16, 16) -> ("pod", "data", "model") = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; smoke tests see
one CPU device).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from ..runtime import jax_compat

__all__ = ["make_production_mesh", "make_mesh", "worker_count"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before importing jax")
    return jax_compat.make_mesh(shape, axes, devices=devs[:n])


def worker_count(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
