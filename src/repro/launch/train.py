"""Training launcher: ``python -m repro.launch.train --arch minicpm-2b
--smoke --steps 100``.

On real hardware the full config + production mesh applies; on CPU the
``--smoke`` flag selects each architecture's reduced config (same code
path, same sharding rules, 1-device mesh).
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule", default="cosine",
                    choices=["constant", "cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.data.pipeline import TokenPipeline
    from repro.models.registry import build, get_config, get_smoke_config
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import TrainLoopConfig, train_loop

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    fns = build(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch, seed=args.seed)

    extra = None
    if cfg.family in ("audio", "encdec"):
        def extra(step):
            rng = np.random.default_rng(1000 + step)
            return {"frames": rng.normal(
                size=(args.global_batch, cfg.encoder_frames, cfg.d_model)
            ).astype(np.float32) * 0.02}
    elif cfg.family == "vlm":
        def extra(step):
            rng = np.random.default_rng(2000 + step)
            return {
                "embeds": rng.normal(
                    size=(args.global_batch, args.seq_len, cfg.d_model)
                ).astype(np.float32) * 0.02,
                "positions3": np.broadcast_to(
                    np.arange(args.seq_len)[None, None],
                    (3, args.global_batch, args.seq_len)).astype(np.int32),
            }

    out = train_loop(
        cfg, fns,
        TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        microbatches=args.microbatches, seed=args.seed,
                        log_every=max(1, args.steps // 20)),
        AdamWConfig(lr=args.lr, schedule=args.schedule,
                    warmup_steps=max(1, args.steps // 10),
                    total_steps=args.steps),
        pipe, resume=args.resume, extra_batch=extra)
    print(f"[train] done: first-5 loss {np.mean(out['losses'][:5]):.4f} "
          f"-> last-5 {np.mean(out['losses'][-5:]):.4f}")


if __name__ == "__main__":
    main()
