"""ShapeDtypeStruct input stand-ins for every (arch × shape × step).

This is the dry-run's data layer: weak-type-correct, shardable, zero
allocation.  Modality frontends are stubs per the assignment —
``[audio]`` gets precomputed mel-frame embeddings, ``[vlm]`` precomputed
patch embeddings + 3-axis M-RoPE positions.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models.registry import build
from ..models.transformer import init_cache

__all__ = ["input_specs", "params_specs", "cache_specs_struct"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Batch ShapeDtypeStructs for the step function this shape lowers."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    dt = jnp.dtype(cfg.dtype)
    batch: dict[str, Any] = {}

    s_tok = 1 if kind == "decode" else S
    if cfg.family == "vlm":
        batch["embeds"] = _sds((B, s_tok, cfg.d_model), dt)
        batch["positions3"] = _sds((3, B, s_tok), jnp.int32)
    else:
        batch["tokens"] = _sds((B, s_tok), jnp.int32)
    if cfg.family in ("audio", "encdec") and kind != "decode":
        batch["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model), dt)
    if kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def params_specs(cfg: ModelConfig) -> Any:
    fns = build(cfg)
    return jax.eval_shape(fns["init"], jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs_struct(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Decode-shape KV/state cache stand-ins (cache len = shape.seq_len)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           dtype=jnp.bfloat16))
