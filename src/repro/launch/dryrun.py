import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and record memory/cost/roofline artifacts.

    python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k \
        --mesh single --out results
    python -m repro.launch.dryrun --all --mesh both --out results

``--all`` orchestrates one subprocess per cell (fresh compile, JSON
result cache keyed on (mesh, arch, shape) — rerunning skips finished
cells).  Skipped cells (long_500k on full-attention archs, per the
assignment) are recorded with reason.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, variant: str = "") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import SHAPES, cell_applicable, shape_lowers
    from repro.launch.mesh import make_production_mesh, worker_count
    from repro.launch.specs import cache_specs_struct, input_specs, params_specs
    from repro.models.registry import build, get_config
    from repro.optim.adamw import AdamWConfig
    from repro.roofline.analysis import analyze
    from repro.runtime.sharding import (active_mesh, batch_specs,
                                        cache_specs, param_shardings,
                                        param_specs)
    from repro.train.train_step import init_train_state, make_train_step

    from repro.runtime.sharding import compute_specs

    cfg = get_config(arch)
    if variant:
        cfg = dataclasses.replace(cfg, **json.loads(variant))
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = worker_count(mesh)
    fns = build(cfg)
    step_name = shape_lowers(shape)

    t0 = time.perf_counter()
    params_sds = params_specs(cfg)
    if (os.environ.get("DRYRUN_DECODE_WEIGHTS") == "replicated"
            and shape.kind == "decode"):
        # serving-mode weights: tp-sharded only, dp-replicated — kills
        # the per-token FSDP all-gather at the cost of (params·2/tp)
        # bytes of HBM per device (§Perf decode iteration)
        p_shard = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            compute_specs(params_sds, mesh),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    else:
        p_shard = param_shardings(params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    b_spec = batch_specs(cfg, mesh, batch_sds)
    b_shard = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), b_spec,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    with mesh, active_mesh(mesh):
        if step_name == "train_step":
            opt_cfg = AdamWConfig()
            # microbatch so each accumulation step carries ~1 sequence per
            # dp shard — the activation-memory/global-batch decoupling a
            # real run needs at these batch sizes (perf lever; see §Perf)
            dp = chips // mesh.shape["model"]
            micro = int(os.environ.get(
                "DRYRUN_MICROBATCHES", max(1, shape.global_batch // dp)))
            step_fn = make_train_step(cfg, opt_cfg, fns["loss_fn"],
                                      microbatches=micro)
            opt_sds = jax.eval_shape(init_train_state, params_sds)
            o_shard = {"m": p_shard, "v": p_shard,
                       "step": jax.sharding.NamedSharding(
                           mesh, jax.sharding.PartitionSpec())}
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            ).lower(params_sds, opt_sds, batch_sds)
        elif step_name == "prefill_step":
            lowered = jax.jit(
                fns["prefill"], in_shardings=(p_shard, b_shard),
            ).lower(params_sds, batch_sds)
        else:   # decode_step
            cache_sds = cache_specs_struct(cfg, shape)
            c_spec = cache_specs(cfg, mesh, cache_sds)
            c_shard = jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), c_spec,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                fns["decode"],
                in_shardings=(p_shard, c_shard, b_shard, None),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, batch_sds, pos)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    memstats = compiled.memory_analysis()
    try:
        costd = compiled.cost_analysis()
    except Exception:
        costd = {}
    hlo = compiled.as_text()
    dump = os.environ.get("DRYRUN_DUMP_HLO")
    if dump:
        with open(dump, "w") as f:
            f.write(hlo)

    micro = 1
    if step_name == "train_step":
        micro = int(os.environ.get("DRYRUN_MICROBATCHES",
                                   max(1, shape.global_batch
                                       // (chips // mesh.shape["model"]))))
    report = analyze(cfg, shape, mesh_name=mesh_kind, chips=chips,
                     step=step_name, hlo_text=hlo, memory_stats=memstats,
                     cost_analysis=costd, tp=mesh.shape["model"],
                     microbatches=micro, notes=variant)
    out = report.to_json()
    out.update({
        "status": "ok",
        "lower_seconds": t_lower,
        "compile_seconds": t_compile,
        "hlo_bytes_len": len(hlo),
    })
    print(f"[dryrun] {cfg.name} {shape_name} {mesh_kind}: "
          f"args={out['argument_bytes']/2**30:.2f}GiB "
          f"temp={out['temp_bytes']/2**30:.2f}GiB "
          f"flops/dev={out['hlo_flops']:.3e} "
          f"bottleneck={out['bottleneck']}")
    print(f"[dryrun] memory_analysis: {memstats}")
    print(f"[dryrun] cost_analysis flops: {costd.get('flops')}")
    return out


def cell_path(out_dir, mesh, arch, shape, variant=""):
    import hashlib
    tag = ""
    if variant:
        tag = "__" + hashlib.sha1(variant.encode()).hexdigest()[:8]
    # normalize to the registry module id so CLI aliases share the cache
    from repro.models.registry import _ALIASES
    safe = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    return os.path.join(out_dir, "dryrun", mesh,
                        f"{safe}__{shape}{tag}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results")
    ap.add_argument("--variant", default="",
                    help="JSON dict of ModelConfig overrides (perf iters)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        from repro.configs.base import SHAPES
        from repro.models.registry import ARCHS, get_config
        jobs = [(a, s, m) for m in meshes for a in ARCHS for s in SHAPES]
        failures = []
        for (a, s, m) in jobs:
            path = cell_path(args.out, m, a, s)
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {m} {a} {s}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out", args.out]
            print(f"[run] {m} {a} {s}")
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
            except subprocess.TimeoutExpired:
                failures.append((m, a, s, "TIMEOUT"))
                print(f"[FAIL-TIMEOUT] {m} {a} {s}")
                continue
            if r.returncode != 0:
                failures.append((m, a, s, r.stderr[-2000:]))
                print(f"[FAIL] {m} {a} {s}\n{r.stderr[-2000:]}")
            else:
                lines = [l for l in r.stdout.strip().splitlines()
                         if l.startswith("[dryrun]") or "skipped" in l]
                print(lines[0] if lines else "[done]")
        print(f"\n{len(failures)} failures")
        for f in failures:
            print("FAILED:", f[0], f[1], f[2])
        sys.exit(1 if failures else 0)

    for m in meshes:
        path = cell_path(args.out, m, args.arch, args.shape, args.variant)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        try:
            res = run_cell(args.arch, args.shape, m, args.out,
                           variant=args.variant)
        except Exception:
            traceback.print_exc()
            sys.exit(1)
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        print(f"[saved] {path}")


if __name__ == "__main__":
    main()
