import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
"""Dry-run of the MIRAGE mining step itself on the production mesh —
the paper-representative roofline cell.

Lowers one level's map+shuffle+reduce (support round) and the survivor
materialization at production-plausible shapes:

    NP = parts_per_device × 512 partitions, G graphs each, P patterns,
    C candidates, M embeddings, F edge occurrences.

The compute body is the reference join (the Pallas kernel's algorithm,
XLA-compiled — the TPU kernel path swaps in on hardware with identical
shapes/dataflow), so the FLOP/byte/collective structure is the real
thing.

    python -m repro.launch.dryrun_mining --mesh both --out results
"""
import argparse
import dataclasses
import json
import time


def run(mesh_kind: str, out_dir: str, *, reduce: str, parts_per_dev: int = 4,
        P: int = 64, C: int = 256, G: int = 2048, M: int = 32, K: int = 6,
        T: int = 64, F: int = 32, minsup: int = 100) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.mapreduce import (MiningMesh, _materialize_program,
                                      _support_program)
    from repro.launch.mesh import make_production_mesh, worker_count
    from repro.roofline.hlo import parse_hlo_cost
    from repro.roofline.hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mmesh = MiningMesh(mesh)
    W = mmesh.n_workers
    NP = parts_per_dev * W
    Cp = ((C + W - 1) // W) * W

    sds = jax.ShapeDtypeStruct
    meta = sds((Cp, 5), jnp.int32)
    pol = sds((NP, P, G, M, K), jnp.int32)
    pmask = sds((NP, P, G, M), jnp.bool_)
    src = sds((NP, T, G, F), jnp.int32)
    dst = sds((NP, T, G, F), jnp.int32)
    emask = sds((NP, T, G, F), jnp.bool_)

    out = {"kind": "mining", "mesh": mesh_kind, "chips": W,
           "reduce": reduce, "parts_per_dev": parts_per_dev,
           "shapes": dict(NP=NP, P=P, C=Cp, G=G, M=M, K=K, T=T, F=F)}
    t0 = time.perf_counter()
    for phase, prog, args in (
            ("support", _support_program(mmesh, minsup, "ref", reduce),
             (meta, pol, pmask, src, dst, emask)),
            ("materialize", _materialize_program(mmesh, M),
             (meta, pol, pmask, src, dst, emask))):
        lowered = prog.lower(*args)
        compiled = lowered.compile()
        cost = parse_hlo_cost(compiled.as_text())
        mem = compiled.memory_analysis()
        # analytic HBM: the join streams pol + eol once per candidate tile
        pol_b = parts_per_dev * P * G * M * K * 4
        eol_b = parts_per_dev * T * G * F * 9
        analytic = pol_b / P * Cp / parts_per_dev + eol_b  # per device
        out[phase] = {
            "flops": cost.flops,
            "hbm_bytes_analytic": analytic,
            "wire_bytes": cost.collective_wire_bytes,
            "collectives": {k: v[0] for k, v in cost.collectives.items()},
            "t_compute": cost.flops / PEAK_FLOPS_BF16,
            "t_memory": analytic / HBM_BW,
            "t_collective": cost.collective_wire_bytes / ICI_BW,
            "temp_bytes": mem.temp_size_in_bytes,
            "argument_bytes": mem.argument_size_in_bytes,
        }
        terms = {k: out[phase][f"t_{k}"]
                 for k in ("compute", "memory", "collective")}
        out[phase]["bottleneck"] = max(terms, key=terms.get)
    out["seconds"] = time.perf_counter() - t0

    os.makedirs(os.path.join(out_dir, "dryrun", mesh_kind), exist_ok=True)
    tag = f"__pp{parts_per_dev}" if parts_per_dev != 4 else ""
    path = os.path.join(out_dir, "dryrun", mesh_kind,
                        f"mirage_mining__{reduce}{tag}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[dryrun-mining] {mesh_kind} reduce={reduce}: "
          f"support bottleneck={out['support']['bottleneck']} "
          f"wire={out['support']['wire_bytes']:.3e}B "
          f"temp={out['support']['temp_bytes']/2**30:.2f}GiB -> {path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results")
    ap.add_argument("--reduce", default="both",
                    choices=["psum", "reduce_scatter", "both"])
    ap.add_argument("--parts-per-dev", type=int, default=4)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    reduces = (["psum", "reduce_scatter"] if args.reduce == "both"
               else [args.reduce])
    for m in meshes:
        for r in reduces:
            run(m, args.out, reduce=r, parts_per_dev=args.parts_per_dev)


if __name__ == "__main__":
    main()
