"""Mining launcher: the paper's end-to-end driver.

    python -m repro.launch.mine --dataset pubchem-like --n-graphs 200 \
        --minsup 0.2 --partitions 8 --scheme 2 --reduce reduce_scatter

Anytime mining (DESIGN.md §14): ``--deadline S`` bounds the whole run's
wall clock and ``--partial-ok`` turns budget/deadline exhaustion into a
verified PARTIAL RESULT (the frequent set through the newest audited
complete level) printed with a ``[mine] PARTIAL RESULT`` marker and
exit code 0 — the JSON written by ``--out`` then carries
``"partial": true``.  ``--level-deadline S`` pins a fixed per-phase
watchdog deadline (deterministic hang detection for CI chaos runs);
``--audit-report PATH`` dumps the continuous invariant auditor's
per-level report.  A malformed input database exits 2 with a one-line
diagnosis (graph id + edge index) instead of a traceback.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="pubchem-like",
                    choices=["pubchem-like", "synthetic", "paper-toy"])
    ap.add_argument("--n-graphs", type=int, default=100)
    ap.add_argument("--avg-edges", type=float, default=12.0)
    ap.add_argument("--minsup", type=float, default=0.2,
                    help="fraction (0,1) or absolute count (>=1)")
    ap.add_argument("--partitions", type=int, default=8)
    ap.add_argument("--scheme", default="2", choices=["1", "2", "density"],
                    help="partition scheme: 1 = graph count, 2 = LPT by "
                         "edges, density = snake-deal by edge density "
                         "(Aridhi et al., arXiv 1212.0017)")
    ap.add_argument("--max-size", type=int, default=None)
    ap.add_argument("--max-embeddings", type=int, default=32)
    ap.add_argument("--reduce", default=None,
                    choices=["psum", "reduce_scatter"],
                    help="shuffle collective (default: reduce_scatter "
                         "for single_sync, psum for legacy)")
    ap.add_argument("--dense-wire", action="store_true",
                    help="disable the sharded wire layout (each worker "
                         "then fetches the FULL support vector)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable overlapped host candidate generation")
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "pallas", "interpret", "fused",
                             "fused_interpret"])
    ap.add_argument("--pipeline", default="single_sync",
                    choices=["single_sync", "device_loop", "legacy"],
                    help="single_sync: one device program + one host "
                         "sync per level (default); device_loop: the "
                         "ENTIRE run as one lax.while_loop program with "
                         "a single device->host transfer (needs "
                         "--max-size); legacy: the PR-1 two-program "
                         "driver")
    ap.add_argument("--candgen", default="host",
                    choices=["host", "device"],
                    help="candidate generation for the per-level "
                         "pipelines: host python generator (default) or "
                         "the jitted device generator (the device_loop "
                         "stepping stone)")
    ap.add_argument("--device-c-budget", type=int, default=None,
                    help="device_loop: canonical candidate budget per "
                         "loop iteration (default: auto-sized)")
    ap.add_argument("--device-raw-budget", type=int, default=None,
                    help="device_loop: structural slot budget before "
                         "canonicality (default: 4x the c-budget)")
    ap.add_argument("--device-max-states", type=int, default=64,
                    help="device canonicality machine state bound")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="device_loop: checkpoint-chunk cadence in "
                         "levels (default: no mid-run checkpoints — "
                         "exactly one transfer per run)")
    ap.add_argument("--unroll", type=int, default=0,
                    help="device_loop: >0 replaces the while_loop with "
                         "this many cond-gated body applications per "
                         "program invocation")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable shape bucketing (one XLA compile per "
                         "mining level instead of per bucket family)")
    ap.add_argument("--bucket-floors", default=None, metavar="C,S,K",
                    help="bucket family floors for the candidate axis, "
                         "survivor cap and vertex slots (default 64,32,8)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write result JSON here")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="chaos mode: inject a deterministic fault "
                         "schedule, e.g. 'worker_loss@2;wire_bitflip@3'"
                         " (see repro.runtime.faults); mining runs "
                         "under the recovery supervisor")
    ap.add_argument("--max-retries", type=int, default=5,
                    help="supervisor recovery-attempt budget")
    ap.add_argument("--fault-log", default=None,
                    help="write the structured fault-event log (JSONL, "
                         "one line per event, crash-safe) here; implies "
                         "supervised mining")
    ap.add_argument("--deadline", type=float, default=None,
                    help="whole-run wall-clock budget in seconds; "
                         "implies supervised mining (DESIGN.md §14)")
    ap.add_argument("--level-deadline", type=float, default=None,
                    help="fixed per-phase watchdog deadline in seconds "
                         "(default: self-calibrating EWMA policy)")
    ap.add_argument("--partial-ok", action="store_true",
                    help="on deadline/retry-budget exhaustion return a "
                         "verified PARTIAL RESULT (exit 0 + marker) "
                         "instead of raising; implies supervised mining")
    ap.add_argument("--no-audit", action="store_true",
                    help="disable the continuous invariant auditor "
                         "(device audit word + host spot checks)")
    ap.add_argument("--audit-report", default=None,
                    help="write the auditor's per-level report JSON here")
    args = ap.parse_args()

    from repro.core.graphdb import (GraphValidationError, paper_toy_db,
                                    pubchem_like_db, random_db)
    from repro.core.mining import Mirage, MirageConfig, PartialResult
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults
    from repro.runtime.watchdog import Watchdog

    if args.dataset == "paper-toy":
        graphs = paper_toy_db()
    elif args.dataset == "pubchem-like":
        graphs = pubchem_like_db(args.n_graphs, seed=args.seed,
                                 avg_edges=args.avg_edges)
    else:
        graphs = random_db(args.n_graphs, seed=args.seed)

    minsup = args.minsup if args.minsup < 1 else int(args.minsup)
    bucket_kw = {}
    if args.bucket_floors:
        c, s, k = (int(x) for x in args.bucket_floors.split(","))
        bucket_kw = dict(bucket_c_floor=c, bucket_s_floor=s,
                         bucket_k_floor=k)
    scheme = args.scheme if args.scheme == "density" else int(args.scheme)
    cfg = MirageConfig(
        minsup=minsup, n_partitions=args.partitions, scheme=scheme,
        max_size=args.max_size, max_embeddings=args.max_embeddings,
        reduce=args.reduce, backend=args.backend,
        sharded_wire=False if args.dense_wire else None,
        overlap_candgen=not args.no_overlap,
        pipeline=args.pipeline, candgen=args.candgen,
        device_c_budget=args.device_c_budget,
        device_raw_budget=args.device_raw_budget,
        device_max_states=args.device_max_states,
        device_loop_ckpt_every=args.ckpt_every,
        device_loop_unroll=args.unroll,
        checkpoint_dir=args.ckpt_dir,
        bucket_shapes=not args.no_bucket,
        audit=not args.no_audit, **bucket_kw)

    supervised = (args.fault_schedule or args.fault_log
                  or args.deadline is not None or args.partial_ok)
    if args.fault_schedule:
        schedule = faults.FaultSchedule.parse(args.fault_schedule)
        faults.install(schedule)
        print(f"[mine] chaos schedule: {schedule.describe()}")

    sup = miner = None
    t0 = time.perf_counter()
    try:
        if supervised:
            watchdog = None
            if args.level_deadline is not None:
                watchdog = Watchdog(run_deadline_s=args.deadline,
                                    phase_default=args.level_deadline)
            sup = MiningSupervisor(
                cfg, SupervisorConfig(
                    max_retries=args.max_retries,
                    fault_log_path=args.fault_log,
                    deadline_s=args.deadline,
                    on_exhausted="partial" if args.partial_ok
                    else "raise"),
                watchdog=watchdog)
            res = sup.mine(graphs, resume=args.resume)
        else:
            miner = Mirage(cfg)
            res = miner.fit(graphs, resume=args.resume)
            if miner.last_device_loop is not None:
                info = miner.last_device_loop
                print(f"[mine] device_loop: completed={info['completed']} "
                      f"chunks={info['chunks']} "
                      f"escalations={info['escalations']}"
                      + (f" fallback={info['fallback']}"
                         if info["fallback"] else ""))
    except GraphValidationError as exc:
        # a malformed database is an input bug, not a crash: diagnose
        # (graph id + edge index) on stderr, no traceback
        print(f"[mine] invalid database: {exc}", file=sys.stderr)
        raise SystemExit(2)
    dt = time.perf_counter() - t0

    if sup is not None and sup.events:
        print(f"[mine] recovered from {len(sup.events)} fault(s):")
        for ev in sup.events:
            print(f"  attempt {ev.attempt}: {ev.kind} at level "
                  f"{ev.level} -> {ev.action} ({ev.detail})")
    if sup is not None and sup.watchdog and sup.watchdog.trips:
        for trip in sup.watchdog.trips:
            print(f"[mine] watchdog trip: level {trip['level']} "
                  f"exceeded {trip['deadline_s']:.2f}s phase deadline "
                  f"after {trip['elapsed_s']:.2f}s")

    partial = isinstance(res, PartialResult)
    if partial:
        print(f"[mine] PARTIAL RESULT ({res.reason}): verified prefix "
              f"through level {res.last_level}, audited={res.audited}")
    print(f"[mine] |G|={len(graphs)} minsup={res.minsup} "
          f"partitions={args.partitions} scheme={args.scheme} "
          f"reduce={cfg.reduce}")
    print(f"[mine] frequent patterns: {sum(res.counts())} "
          f"(per level: {res.counts()})")
    if partial:
        print(f"[mine] wall: {dt:.2f}s")
    else:
        print(f"[mine] wall: {dt:.2f}s  overflow: {res.total_overflow}")
        for st in res.stats:
            print(f"  level {st.level}: candidates={st.n_candidates} "
                  f"frequent={st.n_frequent} {st.seconds:.2f}s "
                  f"(map {st.map_seconds:.2f}s) "
                  f"imbalance={st.imbalance:.2f}"
                  f"{' [rebalanced]' if st.rebalanced else ''}")
    if args.audit_report:
        report = (sup.audit_report if sup is not None
                  else (miner.auditor.report if miner and miner.auditor
                        else []))
        with open(args.audit_report, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[mine] audit report ({len(report)} row(s)) -> "
              f"{args.audit_report}")
    if args.out:
        payload = {
            "n_graphs": len(graphs), "minsup": res.minsup,
            "counts": res.counts(), "seconds": dt,
            "levels": [[list(map(list, c)) for c in lvl]
                       for lvl in res.levels],
        }
        if partial:
            payload.update(partial=True, reason=res.reason,
                           last_level=res.last_level,
                           audited=res.audited)
        with open(args.out, "w") as f:
            json.dump(payload, f)


if __name__ == "__main__":
    main()
