"""§Perf variant runner: executes the hillclimb cells (three chosen
pairs) as --variant dry-runs and prints the before/after table.

    python -m repro.launch.perf_variants --out results
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# (arch, shape, variant-json, env, label)
VARIANTS = [
    # --- cell 2: minicpm prefill (worst useful_ratio) -------------------
    ("minicpm-2b", "prefill_32k", '{"attn_schedule": "tri"}', {},
     "P7 tri attention schedule"),
    ("minicpm-2b", "prefill_32k", '{"prefill_logits": "last"}', {},
     "P8 last-position prefill logits"),
    ("minicpm-2b", "prefill_32k",
     '{"attn_schedule": "tri", "prefill_logits": "last"}', {},
     "P7+P8 combined"),
    # --- cell 1: qwen2-vl train (most collective-bound) -----------------
    ("qwen2-vl-72b", "train_4k", "", {"DRYRUN_MICROBATCHES": "4"},
     "P5 microbatches 16->4"),
    ("qwen2-vl-72b", "train_4k", '{"seq_parallel": true}', {},
     "P6 sequence parallelism"),
    ("qwen2-vl-72b", "train_4k", '{"seq_parallel": true}',
     {"DRYRUN_MICROBATCHES": "4"}, "P5+P6 combined"),
    # --- P5 on the per-ubatch grad-AR diagnosis (qwen2.5 / xlstm) -------
    ("qwen2.5-14b", "train_4k", "", {"DRYRUN_MICROBATCHES": "4"},
     "P5 qwen2.5 microbatches 16->4"),
    ("xlstm-1.3b", "train_4k", "", {"DRYRUN_MICROBATCHES": "4"},
     "P5 xlstm microbatches 16->4"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results")
    ap.add_argument("--timeout", type=int, default=5400)
    args = ap.parse_args()
    env0 = dict(os.environ)
    env0["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", ".."))

    results = []
    for (arch, shape, variant, env_extra, label) in VARIANTS:
        from repro.launch.dryrun import cell_path
        path = cell_path(args.out, "single", arch, shape, variant)
        if env_extra:  # env changes the artifact: tag the filename
            path = path.replace(".json",
                                "__" + "_".join(f"{k}={v}" for k, v in
                                                env_extra.items()) + ".json")
        if not os.path.exists(path):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", "single",
                   "--out", args.out]
            if variant:
                cmd += ["--variant", variant]
            env = dict(env0)
            env.update(env_extra)
            print(f"[variant] {label}: {arch} {shape} {variant} {env_extra}")
            r = subprocess.run(cmd, env=env, timeout=args.timeout,
                               capture_output=True, text=True)
            if r.returncode != 0:
                print(f"[variant-FAIL] {label}\n{r.stderr[-1500:]}")
                continue
            src = cell_path(args.out, "single", arch, shape, variant)
            if src != path and os.path.exists(src):
                os.replace(src, path)
        with open(path) as f:
            d = json.load(f)
        d["_label"] = label
        results.append(d)

    # mining parts-per-dev decoupling (P10)
    for pp in (1, 16):
        cmd = [sys.executable, "-m", "repro.launch.dryrun_mining",
               "--mesh", "single", "--out", args.out,
               "--reduce", "psum", "--parts-per-dev", str(pp)]
        subprocess.run(cmd, env=env0, timeout=args.timeout)

    print("\nlabel | tC | tM | tX | useful | temp GiB")
    for d in results:
        print(f"{d['_label']} | {d['t_compute']:.3f} | {d['t_memory']:.3f}"
              f" | {d['t_collective']:.3f} | {d['useful_ratio']:.3f}"
              f" | {d['temp_bytes']/2**30:.1f}")


if __name__ == "__main__":
    main()
