"""Fault-tolerant checkpointing (mining levels + training steps).

Design goals, per the 1000+-node brief:

  * **Atomic**: write to ``<dir>/.tmp.<step>`` then rename — a killed
    writer never corrupts the latest checkpoint.
  * **Self-describing**: a JSON skeleton mirrors the pytree structure;
    leaves live in one compressed ``.npz`` (bool leaves bit-packed at
    rest, logical shape in the skeleton).  No pickle anywhere.
  * **Integrity-checked**: the manifest records a SHA-256 digest per
    leaf; ``load_pytree`` verifies every leaf on read and raises
    :class:`~repro.runtime.faults.CheckpointIntegrityError` on any
    mismatch, truncation, or unreadable file — silent bit-rot cannot
    reach the miner.  (Pre-digest checkpoints load with verification
    skipped — the manifest simply carries no digests.)
  * **Elastic**: arrays are saved *unsharded* (host-gathered) with their
    logical PartitionSpec recorded, so a restore may target a different
    mesh shape / device count — ``load_pytree(..., shardings=...)``
    re-lays-out every leaf via ``jax.device_put``.
  * **Resumable scan**: ``latest_step`` finds the newest structurally
    complete checkpoint, reaping incomplete step dirs and stale
    ``.tmp.*`` spill dirs from dead writers as it scans (the store is
    single-writer, so a temp dir seen by a scan is garbage by
    definition); ``load_step`` with no explicit step falls back to the
    newest checkpoint that *passes digest verification*, reaping any
    corrupt newer ones.

This is the analogue of MIRAGE's between-iteration HDFS writes: the
reducer output of level k (here: the level-k OL store + frequent codes)
is durably on disk — and provably intact — before level k+1 starts, so
any worker loss replays at most one level.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
import zipfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

from .faults import CheckpointIntegrityError
from . import faults as _faults

__all__ = ["save_pytree", "load_pytree", "latest_step", "save_step",
           "load_step", "all_steps", "ChunkCadence",
           "CheckpointIntegrityError"]

_LEAF = "__leaf__"
_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_PREFIX = ".tmp.ckpt."


def _encode(tree: Any, leaves: list[np.ndarray]) -> Any:
    """JSON skeleton with array leaves replaced by {_LEAF: idx}."""
    if isinstance(tree, dict):
        return {str(k): _encode(v, leaves) for k, v in sorted(tree.items())}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": "tuple" if isinstance(tree, tuple) else "list",
                "items": [_encode(v, leaves) for v in tree]}
    if isinstance(tree, (np.ndarray, jax.Array)):
        a = np.asarray(tree)
        if a.dtype == np.bool_:
            # bool leaves (the OL masks dominate mining checkpoints) are
            # stored bit-packed — 8x smaller at rest, and the digest is
            # taken over the packed bytes, i.e. over what is actually on
            # disk.  The logical shape rides in the skeleton; _decode
            # re-expands, so packed-at-rest is invisible to callers and
            # a run may save packed and resume dense (or vice versa).
            leaves.append(np.packbits(a.reshape(-1)))
            return {_LEAF: len(leaves) - 1, "__packed_bool__": list(a.shape)}
        leaves.append(a)
        return {_LEAF: len(leaves) - 1}
    if tree is None or isinstance(tree, (bool, int, float, str)):
        return {"__val__": tree}
    if isinstance(tree, (np.integer, np.floating)):
        return {"__val__": tree.item()}
    raise TypeError(f"unsupported checkpoint leaf type: {type(tree)}")


def _decode(node: Any, leaves: dict[str, np.ndarray]) -> Any:
    if isinstance(node, dict):
        if _LEAF in node:
            a = leaves[f"a{node[_LEAF]}"]
            shape = node.get("__packed_bool__")
            if shape is not None:
                n = int(np.prod(shape, dtype=np.int64))
                a = np.unpackbits(a, count=n).astype(bool).reshape(shape)
            return a
        if "__val__" in node:
            return node["__val__"]
        if "__seq__" in node:
            seq = [_decode(v, leaves) for v in node["items"]]
            return tuple(seq) if node["__seq__"] == "tuple" else seq
        return {k: _decode(v, leaves) for k, v in node.items()}
    raise TypeError(f"corrupt checkpoint node: {node!r}")


def _digest(a: np.ndarray) -> str:
    """SHA-256 over dtype + shape + raw bytes (C-contiguous)."""
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def save_pytree(path: str, tree: Any, *, metadata: Optional[dict] = None) -> None:
    """Atomically write ``tree`` (nested dict/list/tuple of arrays/scalars)."""
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    leaves: list[np.ndarray] = []
    skeleton = _encode(tree, leaves)
    tmp = tempfile.mkdtemp(prefix=_TMP_PREFIX, dir=parent)
    try:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"skeleton": skeleton, "metadata": metadata or {},
                       "n_leaves": len(leaves),
                       "digests": {f"a{i}": _digest(a)
                                   for i, a in enumerate(leaves)}}, f)
        np.savez_compressed(os.path.join(tmp, "data.npz"),
                            **{f"a{i}": a for i, a in enumerate(leaves)})
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_pytree(path: str, *, shardings: Any = None,
                verify: bool = True) -> tuple[Any, dict]:
    """Load a checkpoint, verifying per-leaf SHA-256 digests when the
    manifest carries them.  Any unreadable, truncated, or
    digest-mismatched state raises :class:`CheckpointIntegrityError`
    (never a silent wrong answer).  If ``shardings`` (a matching pytree
    of ``jax.sharding.Sharding`` or None leaves) is given, leaves are
    placed onto devices accordingly — this is the elastic-restore path:
    the mesh may differ from the one that wrote the checkpoint."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "data.npz")) as z:
            leaves = {k: z[k] for k in z.files}
    except (OSError, ValueError, KeyError, zipfile.BadZipFile,
            zlib.error, EOFError) as e:
        raise CheckpointIntegrityError(
            f"checkpoint {path} is unreadable: {type(e).__name__}: {e}"
        ) from e
    if verify:
        if len(leaves) != manifest.get("n_leaves", len(leaves)):
            raise CheckpointIntegrityError(
                f"checkpoint {path}: payload holds {len(leaves)} leaves, "
                f"manifest promises {manifest.get('n_leaves')}")
        for name, want in manifest.get("digests", {}).items():
            if name not in leaves:
                raise CheckpointIntegrityError(
                    f"checkpoint {path}: leaf {name} missing from payload")
            got = _digest(leaves[name])
            if got != want:
                raise CheckpointIntegrityError(
                    f"checkpoint {path}: leaf {name} digest mismatch "
                    f"(stored {want[:12]}…, loaded {got[:12]}…)")
    tree = _decode(manifest["skeleton"], leaves)
    if shardings is not None:
        def place(x, s):
            if isinstance(x, np.ndarray) and s is not None:
                return jax.device_put(x, s)
            return x
        tree = jax.tree_util.tree_map(
            place, tree, shardings,
            is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
    return tree, manifest["metadata"]


class ChunkCadence:
    """Checkpoint cadence for the device-resident run loop (DESIGN.md
    §13).  The whole-run program checkpoints by RE-INVOCATION: the one
    compiled program runs to a nearer ``k_stop`` (a chunk) and the host
    persists state at each boundary — "every ``every`` levels, or on
    loop exit" when ``every`` is None.  Centralizing the boundary
    arithmetic keeps the driver and the residency gate agreed on how
    many boundaries (and therefore how many device→host fetches) a run
    performs: ``1`` wire fetch without mid-run checkpoints, at most
    ``3·n_chunks`` fetches (wire + OL store + mask per boundary) with
    them."""

    def __init__(self, start: int, stop: int, every: Optional[int] = None):
        if stop < start:
            raise ValueError(f"cadence stop={stop} before start={start}")
        self.start = start
        self.stop = stop
        self.every = (every if every and every > 0
                      else max(stop - start, 1))

    def boundaries(self) -> list[int]:
        """Every chunk's ``k_stop``, in order; the last is ``stop``."""
        out, k = [], self.start
        while k < self.stop:
            k = min(k + self.every, self.stop)
            out.append(k)
        return out

    @property
    def n_chunks(self) -> int:
        return len(self.boundaries())

    def max_fetches(self) -> int:
        """Residency budget: one wire fetch per chunk plus the two
        store fetches of each NON-final boundary's checkpoint."""
        n = self.n_chunks
        return n + 2 * max(n - 1, 0)


def save_step(root: str, step: int, tree: Any, *,
              metadata: Optional[dict] = None, keep: int = 3) -> str:
    """Step-numbered checkpoint with retention."""
    path = os.path.join(root, f"step_{step:010d}")
    meta = dict(metadata or {})
    meta["step"] = step
    save_pytree(path, tree, metadata=meta)
    steps = all_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:010d}"),
                      ignore_errors=True)
    # chaos hook: scheduled disk corruption of the step just written
    _faults.corrupt_checkpoint(path, step)
    return path


def _complete(root: str, name: str) -> bool:
    """Cheap structural check: manifest parses, payload file exists.
    (Payload *content* is digest-verified by ``load_pytree``.)"""
    d = os.path.join(root, name)
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            json.load(f)
    except (OSError, ValueError):
        return False
    return os.path.exists(os.path.join(d, "data.npz"))


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and _complete(root, name):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    """Newest structurally complete step — incomplete step dirs and
    stale ``.tmp.*`` writer spills are reaped, not returned."""
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith(_TMP_PREFIX):
            shutil.rmtree(os.path.join(root, name), ignore_errors=True)
            continue
        m = _STEP_RE.match(name)
        if not m:
            continue
        if _complete(root, name):
            steps.append(int(m.group(1)))
        else:
            shutil.rmtree(os.path.join(root, name), ignore_errors=True)
    return max(steps) if steps else None


def load_step(root: str, step: Optional[int] = None, *,
              shardings: Any = None) -> tuple[Any, dict]:
    """Load a step checkpoint.  With ``step=None``, walks back from the
    newest step until one passes digest verification, reaping each
    corrupt step it skips; raises ``FileNotFoundError`` when no intact
    checkpoint survives.  An explicit ``step`` is loaded strictly
    (corruption raises :class:`CheckpointIntegrityError`)."""
    if step is not None:
        return load_pytree(os.path.join(root, f"step_{step:010d}"),
                           shardings=shardings)
    while True:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints under {root}")
        path = os.path.join(root, f"step_{step:010d}")
        try:
            return load_pytree(path, shardings=shardings)
        except CheckpointIntegrityError:
            # fall back to the previous level's state: strictly better
            # than mining on from corrupt state, and the driver replays
            # the lost level(s) deterministically
            shutil.rmtree(path, ignore_errors=True)
