"""Fault-tolerant checkpointing (mining levels + training steps).

Design goals, per the 1000+-node brief:

  * **Atomic**: write to ``<dir>/.tmp.<step>`` then rename — a killed
    writer never corrupts the latest checkpoint.
  * **Self-describing**: a JSON skeleton mirrors the pytree structure;
    leaves live in one compressed ``.npz``.  No pickle anywhere.
  * **Elastic**: arrays are saved *unsharded* (host-gathered) with their
    logical PartitionSpec recorded, so a restore may target a different
    mesh shape / device count — ``load_pytree(..., shardings=...)``
    re-lays-out every leaf via ``jax.device_put``.
  * **Resumable scan**: ``latest_step`` finds the newest complete
    checkpoint; incomplete temp dirs are ignored (and reaped).

This is the analogue of MIRAGE's between-iteration HDFS writes: the
reducer output of level k (here: the level-k OL store + frequent codes)
is durably on disk before level k+1 starts, so any worker loss replays at
most one level.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "latest_step", "save_step",
           "load_step"]

_LEAF = "__leaf__"
_STEP_RE = re.compile(r"^step_(\d+)$")


def _encode(tree: Any, leaves: list[np.ndarray]) -> Any:
    """JSON skeleton with array leaves replaced by {_LEAF: idx}."""
    if isinstance(tree, dict):
        return {str(k): _encode(v, leaves) for k, v in sorted(tree.items())}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": "tuple" if isinstance(tree, tuple) else "list",
                "items": [_encode(v, leaves) for v in tree]}
    if isinstance(tree, (np.ndarray, jax.Array)):
        leaves.append(np.asarray(tree))
        return {_LEAF: len(leaves) - 1}
    if tree is None or isinstance(tree, (bool, int, float, str)):
        return {"__val__": tree}
    if isinstance(tree, (np.integer, np.floating)):
        return {"__val__": tree.item()}
    raise TypeError(f"unsupported checkpoint leaf type: {type(tree)}")


def _decode(node: Any, leaves: dict[str, np.ndarray]) -> Any:
    if isinstance(node, dict):
        if _LEAF in node:
            return leaves[f"a{node[_LEAF]}"]
        if "__val__" in node:
            return node["__val__"]
        if "__seq__" in node:
            seq = [_decode(v, leaves) for v in node["items"]]
            return tuple(seq) if node["__seq__"] == "tuple" else seq
        return {k: _decode(v, leaves) for k, v in node.items()}
    raise TypeError(f"corrupt checkpoint node: {node!r}")


def save_pytree(path: str, tree: Any, *, metadata: Optional[dict] = None) -> None:
    """Atomically write ``tree`` (nested dict/list/tuple of arrays/scalars)."""
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    leaves: list[np.ndarray] = []
    skeleton = _encode(tree, leaves)
    tmp = tempfile.mkdtemp(prefix=".tmp.ckpt.", dir=parent)
    try:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"skeleton": skeleton, "metadata": metadata or {},
                       "n_leaves": len(leaves)}, f)
        np.savez_compressed(os.path.join(tmp, "data.npz"),
                            **{f"a{i}": a for i, a in enumerate(leaves)})
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_pytree(path: str, *, shardings: Any = None) -> tuple[Any, dict]:
    """Load a checkpoint.  If ``shardings`` (a matching pytree of
    ``jax.sharding.Sharding`` or None leaves) is given, leaves are placed
    onto devices accordingly — this is the elastic-restore path: the mesh
    may differ from the one that wrote the checkpoint."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "data.npz")) as z:
        leaves = {k: z[k] for k in z.files}
    tree = _decode(manifest["skeleton"], leaves)
    if shardings is not None:
        def place(x, s):
            if isinstance(x, np.ndarray) and s is not None:
                return jax.device_put(x, s)
            return x
        tree = jax.tree_util.tree_map(
            place, tree, shardings,
            is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
    return tree, manifest["metadata"]


def save_step(root: str, step: int, tree: Any, *,
              metadata: Optional[dict] = None, keep: int = 3) -> str:
    """Step-numbered checkpoint with retention."""
    path = os.path.join(root, f"step_{step:010d}")
    meta = dict(metadata or {})
    meta["step"] = step
    save_pytree(path, tree, metadata=meta)
    steps = all_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:010d}"),
                      ignore_errors=True)
    return path


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = all_steps(root)
    return steps[-1] if steps else None


def load_step(root: str, step: Optional[int] = None, *,
              shardings: Any = None) -> tuple[Any, dict]:
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    return load_pytree(os.path.join(root, f"step_{step:010d}"),
                       shardings=shardings)
