"""Version-compatibility shims for JAX API drift.

The codebase targets the current JAX API surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=...)``), but must
also run on older installs where ``shard_map`` still lives in
``jax.experimental`` (with ``check_rep`` instead of ``check_vma``) and
meshes carry no axis types.  Every mesh/shard_map construction in the
repo goes through this module so the drift is handled in exactly one
place.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax
from jax.sharding import Mesh

__all__ = ["make_mesh", "shard_map"]


def _make_mesh_takes_axis_types() -> bool:
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):   # signature unavailable — assume new API
        return True


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> Mesh:
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    Newer JAX requires (or defaults differently) ``axis_types``; older
    JAX rejects the kwarg entirely.  Semantics are identical for our
    usage — every axis is a plain Auto/manual-collective axis.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and _make_mesh_takes_axis_types():
        kwargs["axis_types"] = (axis_type.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` if present, else the experimental spelling.

    ``check_vma`` (new name) and ``check_rep`` (old name) toggle the same
    replication/varying-axis checker.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
