"""Deadline watchdog for the mining runtime (DESIGN.md §14).

Since PR 9 put the whole run inside one ``lax.while_loop`` program, a
hung device dispatch has no natural bound: the host thread blocks in a
transfer with nothing watching it.  :class:`Watchdog` restores a bound
in two layers:

  * a **run deadline** (wall-clock budget for the whole ``mine`` call,
    spanning supervisor retries) checked cooperatively at loop heads via
    :meth:`check_run`, raising
    :class:`~repro.runtime.faults.DeadlineExceeded`, and
  * **phase deadlines** — one per level (``single_sync``) or per chunk
    (``device_loop``, where ``ChunkCadence`` boundaries double as
    heartbeats).  The driver arms a phase before dispatch and disarms
    it after the sync; the deadline is ``max(floor, slack x EWMA)`` of
    recent phase wall-times, so it self-calibrates to the workload.

A monitor thread (daemon, started lazily on first arm) wakes when an
armed phase overruns and records a **trip**.  Trips never interrupt the
blocked host thread — a genuinely hung transfer cannot be unwound from
Python — they are a *detection signal*: persisted immediately via the
``on_trip`` callback (the supervisor appends a JSONL line, so a
hard-killed run still leaves evidence) and observed at the next
cooperative point.  The injected-hang hook
(:func:`repro.runtime.faults.maybe_hang`) polls :attr:`tripped` and
raises :class:`~repro.runtime.faults.HangTimeout`, which the supervisor
classifies as the ``hang`` recovery class (device_loop descends the
existing device_loop→single_sync rung; single_sync replays from the
newest checkpoint).

The first phase of a run is never armed from EWMA (there is no sample
yet, and it usually contains compilation); ``phase_default`` pins a
fixed deadline for every phase instead — used by tests and the CLI to
make detection latency deterministic.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from . import faults

__all__ = ["Watchdog"]


class Watchdog:
    """Run-deadline + phase-deadline tracker with a monitor thread.

    Parameters
    ----------
    run_deadline_s:
        Wall-clock budget for the whole run (None = unbounded).
    phase_floor:
        Minimum armed phase deadline in seconds; also the deadline used
        before any EWMA sample exists when > 0.
    phase_slack:
        Multiplier on the EWMA of recent phase wall-times.
    phase_default:
        Fixed phase deadline overriding the EWMA policy entirely
        (deterministic detection for tests / CI).
    on_trip:
        Callback ``on_trip(info: dict)`` invoked from the monitor
        thread when an armed phase overruns.
    """

    def __init__(self, run_deadline_s: Optional[float] = None, *,
                 phase_floor: float = 0.0, phase_slack: float = 8.0,
                 phase_default: Optional[float] = None,
                 ewma_alpha: float = 0.5,
                 clock: Callable[[], float] = time.monotonic,
                 on_trip: Optional[Callable[[dict], None]] = None):
        if phase_slack < 1.0:
            raise ValueError(f"phase_slack must be >= 1: {phase_slack}")
        self.run_deadline_s = run_deadline_s
        self.phase_floor = float(phase_floor)
        self.phase_slack = float(phase_slack)
        self.phase_default = phase_default
        self.ewma_alpha = float(ewma_alpha)
        self._clock = clock
        self.on_trip = on_trip
        self.trips: list[dict] = []
        self._ewma: Optional[float] = None
        self._t0: Optional[float] = None
        self._cv = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        # armed-phase state, guarded by _cv
        self._gen = 0
        self._deadline: Optional[float] = None
        self._armed_at: Optional[float] = None
        self._level: Optional[int] = None
        self._tripped_gen = -1

    # -- run deadline -------------------------------------------------

    def start(self) -> "Watchdog":
        """Start the run clock (idempotent; retries share one clock)."""
        if self._t0 is None:
            self._t0 = self._clock()
        return self

    def elapsed(self) -> float:
        return 0.0 if self._t0 is None else self._clock() - self._t0

    def run_remaining(self) -> Optional[float]:
        """Seconds left on the run deadline (None = unbounded)."""
        if self.run_deadline_s is None:
            return None
        self.start()
        return self.run_deadline_s - self.elapsed()

    @property
    def run_expired(self) -> bool:
        rem = self.run_remaining()
        return rem is not None and rem <= 0

    def check_run(self, level: Optional[int] = None) -> None:
        """Cooperative run-deadline check: raise at loop heads."""
        if self.run_expired:
            raise faults.DeadlineExceeded(level, self.elapsed(),
                                          float(self.run_deadline_s))

    # -- phase deadlines ----------------------------------------------

    def phase_deadline(self) -> Optional[float]:
        """Deadline the next armed phase would get (None = unarmed)."""
        if self.phase_default is not None:
            d = float(self.phase_default)
        elif self._ewma is not None:
            d = max(self.phase_floor, self.phase_slack * self._ewma)
        elif self.phase_floor > 0:
            d = self.phase_floor
        else:
            return None
        rem = self.run_remaining()
        if rem is not None:
            d = min(d, max(rem, 0.0))
        return d

    def arm(self, level: Optional[int] = None,
            deadline_s: Optional[float] = None) -> Optional[float]:
        """Arm a phase (re-arming replaces the current phase).  Returns
        the armed deadline, or None if policy yields no deadline."""
        self.start()
        d = deadline_s if deadline_s is not None else self.phase_deadline()
        with self._cv:
            self._gen += 1
            self._deadline = d
            self._armed_at = self._clock() if d is not None else None
            self._level = level
            self._cv.notify_all()
            if d is not None and self._thread is None:
                self._thread = threading.Thread(
                    target=self._monitor, name="mirage-watchdog",
                    daemon=True)
                self._thread.start()
        return d

    def beat(self, level: Optional[int] = None) -> None:
        """Heartbeat: reset the armed phase timer (chunk progress)."""
        with self._cv:
            if self._deadline is not None:
                self._gen += 1
                self._armed_at = self._clock()
                if level is not None:
                    self._level = level
                self._cv.notify_all()

    def disarm(self, observe_s: Optional[float] = None) -> None:
        """End the phase; optionally feed its wall-time into the EWMA."""
        with self._cv:
            self._gen += 1
            self._deadline = None
            self._armed_at = None
            self._level = None
            self._cv.notify_all()
        if observe_s is not None:
            a = self.ewma_alpha
            self._ewma = (observe_s if self._ewma is None
                          else a * observe_s + (1 - a) * self._ewma)

    @property
    def tripped(self) -> bool:
        """Has the *current* phase crossed its deadline?  Combines the
        monitor thread's flag with a lazy clock check, so detection does
        not depend on thread scheduling."""
        with self._cv:
            if self._deadline is None:
                return False
            if self._tripped_gen == self._gen:
                return True
            return self._clock() - self._armed_at > self._deadline

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._deadline = None
            self._cv.notify_all()

    # -- monitor thread -----------------------------------------------

    def _monitor(self) -> None:
        while True:
            with self._cv:
                if self._closed:
                    return
                gen, deadline = self._gen, self._deadline
                armed_at, level = self._armed_at, self._level
                if deadline is None or self._tripped_gen == gen:
                    self._cv.wait(timeout=0.25)
                    continue
                remaining = deadline - (self._clock() - armed_at)
                if remaining > 0:
                    self._cv.wait(timeout=remaining)
                    continue
                self._tripped_gen = gen
                info = {"event": "watchdog_trip", "level": level,
                        "deadline_s": deadline,
                        "elapsed_s": self._clock() - armed_at,
                        "run_elapsed_s": self.elapsed()}
                self.trips.append(info)
            if self.on_trip is not None:      # outside the lock
                try:
                    self.on_trip(info)
                except Exception:
                    pass                      # logging must never kill us
