"""Sharding rules: parameter-path -> PartitionSpec (FSDP + TP + EP).

Axis roles on the production mesh (launch/mesh.py):
  "model"          tensor parallelism: attention heads / ffn hidden /
                   vocab / experts (EP)
  "data" (+"pod")  data parallelism over the batch AND the FSDP shard
                   axis for parameter/optimizer-state storage (ZeRO-3:
                   XLA all-gathers weights per layer on use because the
                   batch dims are data-sharded)

Rules are name-based over the param pytree paths, so every architecture
(dense/MoE/MLA/SSM/xLSTM/enc-dec) gets covered by one table; anything
unmatched stays replicated (norm scales, biases, small gates).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs",
           "fsdp_axes", "logical_rules", "active_mesh", "shard_hint",
           "partition_sharding"]


# ---------------------------------------------------------------------------
# mining-store sharding
# ---------------------------------------------------------------------------
def partition_sharding(mesh: Mesh) -> NamedSharding:
    """Partition-major NamedSharding for the mining stores (OL, edge-OL:
    dim 0 is the graph-partition axis, blocked over every mesh axis).

    The one placement rule of the mining side, shared by the driver's
    device_put, checkpoint-resume resharding and the parent rebuild —
    and the invariant the SHARDED level wire leans on: blocked dim-0
    sharding means device order IS partition/key order, so concatenated
    wire shards reassemble by simple concatenation (DESIGN.md §11)."""
    return NamedSharding(mesh, P(mesh.axis_names))

# ---------------------------------------------------------------------------
# activation sharding hints
# ---------------------------------------------------------------------------
_ACTIVE_MESH: list[Optional[Mesh]] = [None]


class active_mesh:
    """Context manager the launcher/dry-run uses so model code can emit
    with_sharding_constraint hints (no-op when no mesh is active — smoke
    tests and single-device runs trace the same code unchanged)."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        _ACTIVE_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()


def shard_hint(x, *dims: Any):
    """Constrain activation sharding.  ``dims`` entries: "dp" (the fsdp/
    batch axes), "model", None, or tuples thereof.  Axes that don't exist
    on the active mesh or don't divide the dim are dropped."""
    mesh = _ACTIVE_MESH[-1]
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    parts = []
    for dim_size, d in zip(x.shape, dims):
        axes: tuple = ()
        if d == "dp":
            axes = fsdp_axes(mesh)
        elif d is None:
            parts.append(None)
            continue
        elif isinstance(d, str):
            axes = (d,) if d in names else ()
        else:
            axes = tuple(a for a in d if a in names)
        axes = tuple(a for a in axes if a in names)
        if not axes:
            parts.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        parts.append(axes if dim_size % size == 0 and dim_size >= size
                     else None)
    spec = P(*parts)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes (pod+data on multi-pod meshes)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def logical_rules(mesh: Mesh) -> list[tuple[str, P]]:
    """(path-regex, spec) — first match wins.  Regexes are matched against
    '/'-joined param paths like 'group_0/attn/wq'."""
    dp = fsdp_axes(mesh)          # e.g. ("data",) or ("pod", "data")
    d, m = P(dp), "model"
    return [
        # embeddings / lm head: vocab on model, d_model on fsdp
        (r"embed$", P(m, dp)),
        (r"lm_head$", P(dp, m)),
        # attention: heads on model, d_model on fsdp
        (r"attn/wq$", P(dp, m, None)),
        (r"attn/wk$", P(dp, m, None)),
        (r"attn/wv$", P(dp, m, None)),
        (r"attn/wo$", P(m, None, dp)),
        (r"attn/b[qkv]$", P(m, None)),
        # MLA: lora dims on model where possible
        (r"attn/w_dkv$", P(dp, m)),
        (r"attn/w_kr$", P(dp, None)),
        (r"attn/w_uk$", P(None, m, None)),
        (r"attn/w_uv$", P(None, m, None)),
        (r"attn/w_dq$", P(dp, m)),
        (r"attn/w_uq$", P(None, m, None)),
        # dense mlp: hidden on model
        (r"mlp/w_(up|gate)$", P(dp, m)),
        (r"mlp/w_down$", P(m, dp)),
        # MoE: expert parallelism (experts on model), fsdp inside expert
        (r"moe/router$", P(dp, None)),
        (r"moe/w_(up|gate)$", P(m, dp, None)),
        (r"moe/w_down$", P(m, dp, None)),
        (r"moe/shared/w_(up|gate)$", P(dp, m)),
        (r"moe/shared/w_down$", P(m, dp)),
        # mamba2: inner channels on model
        (r"mixer/w_in$", P(dp, m)),
        (r"mixer/w_out$", P(m, dp)),
        (r"mixer/conv$", P(None, m)),
        # xlstm
        (r"mixer/w(q|k|v)$", P(dp, m, None)),
        (r"mixer/wo$", P(m, None, dp)),
        (r"mixer/ogate$", P(dp, m, None)),
        (r"mixer/w_zifo$", P(dp, None, m, None)),
        (r"mixer/r_zifo$", P(None, m, None, None)),
        # shared attention (zamba2) — same as attn
        (r"shared_attn/wq$", P(dp, m, None)),
        (r"shared_attn/wk$", P(dp, m, None)),
        (r"shared_attn/wv$", P(dp, m, None)),
        (r"shared_attn/wo$", P(m, None, dp)),
        # ---- NO head_dim fallbacks.  Two measured refutations
        # (EXPERIMENTS.md §Perf P3/P12): sharding q/k head_dim all-reduces
        # (B,H,qc,kc) score blocks (10-50x wire blowup), and sharding v/o
        # head_dim all-reduces the P·V accumulator inside the chunked
        # attention backward (74% of qwen2.5 train wire, 3.6 TiB/step).
        # Archs whose head count doesn't divide the model axis keep
        # attention weights model-REPLICATED (dp-sharded storage with
        # ZeRO-3 use-site gather): the honest cost is replicated score
        # compute, visible in useful_ratio; the production fix is tp=8 or
        # head padding, out of scope for the assignment-fixed mesh.
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for(path_str: str, leaf, rules, mesh: Mesh) -> P:
    """Best-fitting matching rule: rules are tried in order and the first
    one that survives `_fit` with the most sharded dims wins (fallback
    rules later in the table cover awkward head counts)."""
    ndim = len(leaf.shape)
    best, best_n = P(), 0
    for rx, spec in rules:
        if not re.search(rx, path_str):
            continue
        parts = list(spec)
        extra = ndim - len(parts)   # group-stacked leading (repeat,) dim
        if extra < 0:
            continue
        fitted = _fit(P(*([None] * extra + parts)), leaf, mesh)
        n = sum(1 for p in fitted if p is not None)
        if n > best_n:
            best, best_n = fitted, n
    return best


def _fit(spec: P, leaf, mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the dim (tiny smoke shapes
    or head counts < mesh axis)."""
    out = []
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % size == 0 and dim >= size else None)
    return P(*out)


def compute_specs(params: Any, mesh: Mesh) -> Any:
    """Use-site (ZeRO-3 'gathered') specs: the storage spec with the dp
    axes stripped — weights stay TP-sharded on 'model' but are gathered
    over the fsdp axes for the matmul."""
    dp = set(fsdp_axes(mesh))

    def strip(spec: P) -> P:
        out = []
        for part in spec:
            if part is None:
                out.append(None)
            elif isinstance(part, str):
                out.append(None if part in dp else part)
            else:
                kept = tuple(a for a in part if a not in dp)
                out.append(kept if kept else None)
        return P(*out)

    return jax.tree_util.tree_map(
        strip, param_specs(params, mesh),
        is_leaf=lambda x: isinstance(x, P))


def gather_for_compute(params: Any, cast=None) -> Any:
    """ZeRO-3 use-site gather: constrain every weight to its compute spec
    (model-sharded only).  Called INSIDE the layer scan body so XLA
    materializes one layer's gathered weights at a time — this is what
    turns the naive 'partial-sum + all-reduce the activations' lowering
    into 'all-gather the (much smaller) weights', per-layer.

    ``cast``: compute dtype applied to >=2-D float leaves BEFORE the
    gather — gathering the bf16 compute copy instead of the f32 master
    halves the FSDP wire bytes (§Perf P11).  Grads still flow in f32
    upstream of the cast (standard mixed precision).

    No-op without an active mesh (smoke tests / single device).
    """
    mesh = _ACTIVE_MESH[-1]
    if mesh is None:
        return params
    rules = logical_rules(mesh)
    dp = set(fsdp_axes(mesh))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        spec = _spec_for(_path_str(path), leaf, rules, mesh)
        parts = []
        for part in spec:
            if part is None or (isinstance(part, str) and part in dp):
                parts.append(None)
            elif isinstance(part, str):
                parts.append(part)
            else:
                kept = tuple(a for a in part if a not in dp)
                parts.append(kept if kept else None)
        if (cast is not None and leaf.ndim >= 2
                and leaf.dtype == jnp.float32):
            leaf = leaf.astype(cast)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*parts))))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpecs matching ``params``."""
    rules = logical_rules(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_spec_for(_path_str(path), leaf, rules, mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh),
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg, mesh: Mesh, batch: Any) -> Any:
    """Batch arrays: leading batch dim over the DP axes (replicated when
    the batch doesn't divide, e.g. long_500k's batch=1)."""
    dp = fsdp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(path, leaf):
        name = _path_str(path)
        if name == "positions3":                 # (3, B, S)
            ok = leaf.shape[1] % dp_size == 0 and leaf.shape[1] >= dp_size
            return P(None, dp if ok else None, None)
        ok = leaf.shape[0] % dp_size == 0 and leaf.shape[0] >= dp_size
        rest = (None,) * (len(leaf.shape) - 1)
        return P(dp if ok else None, *rest)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def cache_specs(cfg, mesh: Mesh, cache: Any) -> Any:
    """Decode-cache sharding.

    KV caches (leaves named k/v/ckv/kr; layout (repeat, B, T, ...)):
      * batch over DP when divisible, else the SEQUENCE dim takes DP
        (context-parallel decode — the long_500k batch=1 case);
      * kv-heads dim over "model" when divisible (GQA often has fewer kv
        heads than the model axis), else "model" also lands on the
        sequence dim — attention reduces over T, so XLA inserts one
        psum over model for the logits, which beats replicating a
        multi-GiB cache.
    Recurrent states (ssm/mlstm/slstm): batch over DP, heads over model.
    """
    dp = fsdp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape["model"]

    def spec(path, leaf):
        shp = leaf.shape           # stacked: (repeat, B, ...)
        parts: list = [None] * len(shp)
        name = _path_str(path).rsplit("/", 1)[-1]
        is_kv = name in ("k", "v", "ckv", "kr")
        if len(shp) < 2:
            return P(*parts)
        batch_ok = shp[1] % dp_size == 0 and shp[1] >= dp_size
        if batch_ok:
            parts[1] = dp
        if is_kv and len(shp) >= 3:
            seq_axes: list = []
            if not batch_ok:
                seq_axes.extend(dp)
            heads_ok = (len(shp) >= 4 and shp[3] % msize == 0
                        and shp[3] >= msize)
            if heads_ok:
                parts[3] = "model"
            else:
                seq_axes.append("model")
            if seq_axes:
                size = int(np.prod([mesh.shape[a] for a in seq_axes]))
                if shp[2] % size == 0 and shp[2] >= size:
                    parts[2] = tuple(seq_axes)
        else:
            # recurrent state: try heads dim (index 2) on model
            if len(shp) >= 3 and shp[2] % msize == 0 and shp[2] >= msize:
                parts[2] = "model"
        return P(*parts)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])
