"""Deterministic fault injection + the mining failure taxonomy
(DESIGN.md §10).

MIRAGE's credibility as a MapReduce reproduction rests on surviving the
failures MapReduce was built for — worker loss, corrupted spills, flaky
links.  This module makes those failures *first-class, reproducible
inputs*:

  * a declarative, seedable **schedule** of :class:`FaultSpec` entries
    (``FaultSchedule.parse`` for the CLI, ``FaultSchedule.random`` for
    property tests),
  * an **injection engine** (``install``/``active``) consulted by hooks
    compiled into the production code paths — the level loop in
    ``core/mining.py`` (worker loss, survivor-cap storms), the program
    dispatch and wire fetch in ``core/level_step.py`` (kernel faults,
    wire bit-flips), and the save path in ``runtime/checkpoint.py``
    (on-disk corruption).  Injection perturbs the real runtime; nothing
    is mocked,
  * the shared **failure taxonomy** the supervisor
    (``core/supervisor.py``) classifies: injected faults
    (:class:`WorkerLost`, :class:`KernelFault`) and detected integrity
    violations (:class:`WireIntegrityError`,
    :class:`CheckpointIntegrityError`).

Every firing is appended to ``injection_log()`` so tests and the CI
chaos job can assert exactly which fault exercised which level.  With
no schedule installed every hook is a no-op costing one attribute read.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Iterable, Optional

import numpy as np

__all__ = [
    "KINDS",
    "InjectedFault", "WorkerLost", "KernelFault", "HangTimeout",
    "IntegrityError", "WireIntegrityError", "CheckpointIntegrityError",
    "AuditError", "DeadlineExceeded",
    "FaultSpec", "FaultSchedule",
    "install", "clear", "active", "installed",
    "injection_log", "reset_log",
    "maybe_raise", "maybe_hang", "corrupt_wire", "override_cap",
    "corrupt_checkpoint", "damage_checkpoint",
]

KINDS = ("worker_loss", "kernel_fault", "wire_bitflip", "ckpt_corrupt",
         "cap_storm", "hang")

_CKPT_MODES = ("flip", "truncate", "manifest")


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------

class InjectedFault(RuntimeError):
    """A scheduled fault fired.  Carries where and what for the
    supervisor's classifier and the structured fault log."""

    kind = "injected"

    def __init__(self, level: int, detail: str = ""):
        self.level = level
        self.detail = detail
        super().__init__(
            f"injected {self.kind} at level {level}"
            + (f" ({detail})" if detail else ""))


class WorkerLost(InjectedFault):
    """A worker died mid-level (the MapReduce headline failure)."""

    kind = "worker_loss"

    def __init__(self, level: int, worker: int = 0):
        self.worker = worker
        super().__init__(level, f"worker {worker}")


class KernelFault(InjectedFault):
    """The level program's kernel dispatch blew up (XLA / Mosaic /
    device-side abort)."""

    kind = "kernel_fault"


class HangTimeout(RuntimeError):
    """A stalled device phase crossed its watchdog deadline.  Raised
    from the cooperative hang hook (:func:`maybe_hang`) when an injected
    stall is caught by an armed :class:`~repro.runtime.watchdog.Watchdog`
    — the detection path a real hang would take if the dispatch ever
    returned.  ``waited_s`` is the observed detection latency."""

    kind = "hang"

    def __init__(self, level: int, waited_s: float = 0.0):
        self.level = level
        self.waited_s = waited_s
        super().__init__(
            f"stalled device phase at level {level} "
            f"(watchdog tripped after {waited_s:.2f}s)")


class DeadlineExceeded(RuntimeError):
    """The whole-run deadline passed.  Not a retryable fault: the
    supervisor routes it straight to the partial-result path (or
    re-raises under ``on_exhausted='raise'``)."""

    kind = "deadline"

    def __init__(self, level: Optional[int], elapsed_s: float,
                 deadline_s: float):
        self.level = level
        self.elapsed_s = elapsed_s
        self.deadline_s = deadline_s
        at = f" at level {level}" if level is not None else ""
        super().__init__(
            f"run deadline {deadline_s:.2f}s exceeded{at} "
            f"(elapsed {elapsed_s:.2f}s)")


class IntegrityError(RuntimeError):
    """Base for *detected* state corruption (checksums, digests)."""


class WireIntegrityError(IntegrityError):
    """The packed device→host wire failed its checksum word."""


class CheckpointIntegrityError(IntegrityError):
    """A checkpoint failed its manifest digests (or cannot be read)."""


class AuditError(IntegrityError):
    """The continuous invariant auditor caught a violated mining
    invariant (support monotonicity, downward closure, canonicality,
    verdict consistency).  State-class: the mined state can no longer be
    trusted, so the supervisor heals by checkpoint replay."""

    def __init__(self, level: int, detail: str):
        self.level = level
        self.detail = detail
        super().__init__(f"audit failure at level {level}: {detail}")


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: ``kind`` at mining ``level``, firing up to
    ``times`` consecutive matches.  Extra knobs are per-kind: ``worker``
    (worker_loss), ``word``/``bit`` (wire_bitflip; word -1 = middle of
    the wire), ``mode`` (ckpt_corrupt: flip|truncate|manifest), ``cap``
    (cap_storm's forced survivor cap), ``secs`` (hang: how long the
    stall lasts before clearing on its own when no watchdog catches
    it)."""

    kind: str
    level: int
    times: int = 1
    worker: int = 0
    word: int = -1
    bit: int = 7
    mode: str = "flip"
    cap: int = 1
    secs: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS})")
        if self.mode not in _CKPT_MODES:
            raise ValueError(f"unknown ckpt_corrupt mode {self.mode!r} "
                             f"(one of {_CKPT_MODES})")
        if self.level < 1 or self.times < 1:
            raise ValueError(f"level/times must be >= 1: {self}")
        self._remaining = self.times

    @staticmethod
    def parse(text: str) -> "FaultSpec":
        """``kind@level[*times][:key=val,...]`` — e.g.
        ``kernel_fault@3*4`` or ``wire_bitflip@2:word=5,bit=12``."""
        text = text.strip()
        head, _, opts = text.partition(":")
        kind, _, at = head.partition("@")
        if not at:
            raise ValueError(f"fault spec {text!r} needs '@level'")
        lvl, _, times = at.partition("*")
        kw: dict = {"kind": kind.strip(), "level": int(lvl),
                    "times": int(times) if times else 1}
        for item in filter(None, (o.strip() for o in opts.split(","))):
            key, _, val = item.partition("=")
            if key not in ("worker", "word", "bit", "mode", "cap", "secs"):
                raise ValueError(f"unknown fault option {key!r} in {text!r}")
            if key == "mode":
                kw[key] = val
            elif key == "secs":
                kw[key] = float(val)
            else:
                kw[key] = int(val)
        return FaultSpec(**kw)


class FaultSchedule:
    """An ordered set of :class:`FaultSpec`; ``install`` arms it (resets
    per-spec firing budgets) so one schedule object replays
    deterministically across runs."""

    def __init__(self, specs: Iterable[FaultSpec]):
        self.specs = list(specs)

    @classmethod
    def parse(cls, text: str) -> "FaultSchedule":
        """Semicolon-separated spec list (commas are taken by per-spec
        options): ``"worker_loss@2;wire_bitflip@3:bit=12"``."""
        return cls(FaultSpec.parse(p) for p in text.split(";") if p.strip())

    @classmethod
    def random(cls, seed: int, *, max_level: int = 4,
               n_faults: int = 2,
               kinds: tuple = KINDS) -> "FaultSchedule":
        """Seed-deterministic schedule for the chaos property suite."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            specs.append(FaultSpec(
                kind=kind,
                level=int(rng.integers(2, max(3, max_level + 1))),
                times=int(rng.integers(1, 3)),
                worker=int(rng.integers(0, 2)),
                word=-1 if rng.random() < 0.5 else int(rng.integers(0, 64)),
                bit=int(rng.integers(0, 30)),
                mode=_CKPT_MODES[int(rng.integers(len(_CKPT_MODES)))],
                cap=1,
                secs=0.05,       # unwatched stalls self-clear fast
            ))
        return cls(specs)

    def arm(self) -> "FaultSchedule":
        for s in self.specs:
            s._remaining = s.times
        return self

    def describe(self) -> str:
        return "; ".join(
            f"{s.kind}@{s.level}" + (f"*{s.times}" if s.times > 1 else "")
            for s in self.specs) or "<empty>"


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

_active_schedule: Optional[FaultSchedule] = None
_log: list[dict] = []


def install(schedule: FaultSchedule) -> None:
    global _active_schedule
    _active_schedule = schedule.arm()


def clear() -> None:
    global _active_schedule
    _active_schedule = None


def installed() -> Optional[FaultSchedule]:
    return _active_schedule


@contextlib.contextmanager
def active(schedule: FaultSchedule):
    install(schedule)
    try:
        yield schedule
    finally:
        clear()


def injection_log() -> list[dict]:
    """Structured record of every fault that actually fired."""
    return list(_log)


def reset_log() -> None:
    _log.clear()


def _take(kind: str, level: Optional[int]) -> Optional[FaultSpec]:
    """Consume one firing of the first armed spec matching (kind, level)."""
    sched = _active_schedule
    if sched is None or level is None:
        return None
    for spec in sched.specs:
        if spec.kind == kind and spec.level == level and spec._remaining > 0:
            spec._remaining -= 1
            _log.append({"kind": kind, "level": level,
                         "remaining": spec._remaining})
            return spec
    return None


# ---------------------------------------------------------------------------
# hooks (called from production code paths)
# ---------------------------------------------------------------------------

def maybe_raise(point: str, level: Optional[int]) -> None:
    """Raise the scheduled fault for this (point, level), if any.

    ``level_start`` (mining driver loop)  → :class:`WorkerLost`
    ``kernel``      (level-program dispatch) → :class:`KernelFault`
    """
    if _active_schedule is None:
        return
    if point == "level_start":
        spec = _take("worker_loss", level)
        if spec is not None:
            raise WorkerLost(level, spec.worker)
    elif point == "kernel":
        spec = _take("kernel_fault", level)
        if spec is not None:
            raise KernelFault(level, "injected dispatch failure")


def maybe_hang(point: str, level: Optional[int], watchdog=None) -> None:
    """Simulate a stalled device phase at (point, level), if scheduled.

    The stall blocks in small slices polling the watchdog.  When an
    armed watchdog trips (phase deadline or run deadline), the stall is
    *detected*: :class:`HangTimeout` carries the observed latency.  With
    no watchdog (or one that never trips) the stall clears on its own
    after ``spec.secs`` — a transient slowdown the run rides out.
    """
    spec = _take("hang", level)
    if spec is None:
        return
    t0 = time.monotonic()
    while True:
        waited = time.monotonic() - t0
        if watchdog is not None and (watchdog.tripped
                                     or watchdog.run_expired):
            raise HangTimeout(level, waited)
        if waited >= spec.secs:
            return                        # stall cleared below deadline
        time.sleep(min(0.005, max(0.0, spec.secs - waited)))


def corrupt_wire(wire: np.ndarray, level: Optional[int]) -> np.ndarray:
    """Flip one bit of the packed int32 wire (a host-link/DMA upset).
    Returns a corrupted *copy* — the device buffer (and jax's cached
    host value) stay pristine, so a re-fetch recovers."""
    spec = _take("wire_bitflip", level)
    if spec is None:
        return wire
    out = wire.copy()
    word = spec.word if 0 <= spec.word < out.shape[0] else out.shape[0] // 2
    out[word] ^= np.int32(1 << (spec.bit % 31))
    return out


def override_cap(cap: int, level: Optional[int]) -> int:
    """Force a pathological survivor cap (a cap-miss storm: every level
    hit must take the materialize-only retry path)."""
    spec = _take("cap_storm", level)
    return cap if spec is None else max(1, spec.cap)


def corrupt_checkpoint(path: str, step: int) -> None:
    """Scheduled on-disk corruption of a just-written checkpoint step."""
    spec = _take("ckpt_corrupt", step)
    if spec is not None:
        damage_checkpoint(path, spec.mode)


def damage_checkpoint(path: str, mode: str = "flip") -> None:
    """Corrupt a checkpoint directory in place (also used directly by
    the chaos tests): ``flip`` a byte of the largest leaf's *compressed
    payload* inside ``data.npz`` (flipping blindly mid-file can land in
    inert zip header metadata — a flip that corrupts nothing), ``truncate``
    the payload, or replace ``manifest.json`` with junk."""
    if mode == "manifest":
        with open(os.path.join(path, "manifest.json"), "w") as f:
            f.write('{"skeleton": ')          # unparseable on purpose
        return
    data = os.path.join(path, "data.npz")
    size = os.path.getsize(data)
    if mode == "truncate":
        with open(data, "r+b") as f:
            f.truncate(max(1, size // 2))
        return
    target = size // 2                        # mode == "flip"
    try:
        import struct
        import zipfile
        with zipfile.ZipFile(data) as z:
            info = max(z.infolist(), key=lambda i: i.compress_size)
        with open(data, "rb") as f:
            f.seek(info.header_offset + 26)
            nlen, elen = struct.unpack("<HH", f.read(4))
        payload = info.header_offset + 30 + nlen + elen
        target = payload + info.compress_size // 2
    except Exception:                         # already-mangled archive:
        pass                                  # fall back to mid-file
    with open(data, "r+b") as f:
        f.seek(target)
        byte = f.read(1)
        f.seek(target)
        f.write(bytes([byte[0] ^ 0xFF]))
