"""Dense tensor encoding of a labeled-graph transaction database.

The paper's input is a database ``G = {G_1..G_n}`` of labeled, undirected,
connected graphs (PubChem molecules / Graphgen synthetics).  Hadoop-MIRAGE
keeps each partition as adjacency lists in Java objects; on TPU we need a
fixed-shape, masked, integer encoding so a partition is a handful of dense
arrays that `shard_map` can lay across the mesh.

Encoding (one partition, ``G`` graphs padded to ``V`` vertices / ``E``
undirected edges):

  vlabels : (G, V)  int32   vertex labels, -1 where padded
  edges   : (G, E, 2) int32 endpoints (u < v), 0 where padded
  elabels : (G, E)  int32   edge labels, -1 where padded
  emask   : (G, E)  bool    real-edge mask
  nglobal : ()      int32   number of real graphs in the partition

Vertex ids are 0-based and dense per graph.  Undirected edges are stored
once with u < v; the mining layer expands both directions when needed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Graph",
    "GraphDB",
    "GraphValidationError",
    "validate_db",
    "encode_db",
    "decode_db",
    "random_db",
    "pubchem_like_db",
]


class GraphValidationError(ValueError):
    """A malformed input graph database (DESIGN.md §10: garbage is
    rejected at the door, never mined into silently wrong supports)."""


@dataclasses.dataclass
class Graph:
    """Host-side labeled undirected graph (adjacency-list form)."""

    vlabels: np.ndarray            # (n_v,) int
    edges: np.ndarray              # (n_e, 2) int, u < v
    elabels: np.ndarray            # (n_e,) int

    def __post_init__(self) -> None:
        self.vlabels = np.asarray(self.vlabels, dtype=np.int32)
        self.edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)
        self.elabels = np.asarray(self.elabels, dtype=np.int32)
        if self.edges.size:
            lo = np.minimum(self.edges[:, 0], self.edges[:, 1])
            hi = np.maximum(self.edges[:, 0], self.edges[:, 1])
            self.edges = np.stack([lo, hi], axis=1)

    @property
    def n_vertices(self) -> int:
        return int(self.vlabels.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def neighbors(self, u: int) -> list[tuple[int, int]]:
        """List of (vertex, edge-label) incident to ``u``."""
        out = []
        for (a, b), el in zip(self.edges, self.elabels):
            if a == u:
                out.append((int(b), int(el)))
            elif b == u:
                out.append((int(a), int(el)))
        return out

    def keep_edges(self, keep: np.ndarray) -> "Graph":
        """Return a copy with only the edges where ``keep`` is True,
        dropping now-isolated vertices and re-densifying vertex ids.

        ``keep`` is a KEEP mask, not a drop mask::

            >>> g = Graph(np.array([0, 1, 2]),
            ...           np.array([[0, 1], [1, 2]]), np.array([7, 8]))
            >>> g.keep_edges(np.array([True, False])).n_edges  # keeps 0-1
            1
        """
        edges = self.edges[keep]
        elabels = self.elabels[keep]
        used = np.zeros(self.n_vertices, dtype=bool)
        if edges.size:
            used[edges.reshape(-1)] = True
        remap = -np.ones(self.n_vertices, dtype=np.int32)
        remap[used] = np.arange(int(used.sum()), dtype=np.int32)
        new_edges = remap[edges] if edges.size else edges
        return Graph(self.vlabels[used], new_edges, elabels)


@dataclasses.dataclass
class GraphDB:
    """Dense-encoded database (or one partition of it)."""

    vlabels: np.ndarray   # (G, V) int32, -1 pad
    edges: np.ndarray     # (G, E, 2) int32
    elabels: np.ndarray   # (G, E) int32, -1 pad
    emask: np.ndarray     # (G, E) bool
    n_graphs: int         # real graph count (<= G)

    @property
    def shape(self) -> tuple[int, int, int]:
        g, v = self.vlabels.shape
        e = self.edges.shape[1]
        return g, v, e

    @property
    def n_vertex_labels(self) -> int:
        return int(self.vlabels.max()) + 1 if self.vlabels.size else 0

    @property
    def n_edge_labels(self) -> int:
        m = int(self.elabels.max()) if self.elabels.size else -1
        return m + 1

    def arrays(self) -> dict[str, np.ndarray]:
        return {
            "vlabels": self.vlabels,
            "edges": self.edges,
            "elabels": self.elabels,
            "emask": self.emask,
        }


def validate_db(graphs: Sequence[Graph]) -> None:
    """Validate a user-supplied transaction database at the load
    boundary (``make_partitions`` calls this before any filtering).

    Rejected with a :class:`GraphValidationError` naming the offending
    graph AND (for per-edge defects) the offending edge index: empty
    graphs, negative vertex/edge labels, edge-label arrays not matching
    the edge count, dangling edge endpoints (out of ``[0, n_v)``),
    self-loops, and duplicate undirected edges.  Only
    *user input* is checked — internally derived graphs (e.g. after
    infrequent-edge filtering, which legitimately empties graphs) never
    pass through here.
    """
    if len(graphs) == 0:
        raise GraphValidationError("empty database: no graphs to mine")
    for i, g in enumerate(graphs):
        if not isinstance(g, Graph):
            raise GraphValidationError(
                f"graph {i}: expected a Graph, got {type(g).__name__}")
        if g.n_vertices == 0:
            raise GraphValidationError(f"graph {i}: no vertices")
        if g.elabels.shape[0] != g.n_edges:
            raise GraphValidationError(
                f"graph {i}: {g.n_edges} edges but "
                f"{g.elabels.shape[0]} edge labels")
        if g.vlabels.min(initial=0) < 0:
            raise GraphValidationError(
                f"graph {i}: negative vertex label "
                f"{int(g.vlabels.min())}")
        if g.n_edges == 0:
            continue
        if g.elabels.min() < 0:
            j = int(np.flatnonzero(g.elabels < 0)[0])
            raise GraphValidationError(
                f"graph {i}, edge {j}: negative edge label "
                f"{int(g.elabels[j])}")
        bad = np.flatnonzero((g.edges < 0).any(axis=1)
                             | (g.edges >= g.n_vertices).any(axis=1))
        if bad.size:
            j = int(bad[0])
            u, v = (int(x) for x in g.edges[j])
            raise GraphValidationError(
                f"graph {i}, edge {j}: dangling edge endpoint "
                f"{u if u < 0 or u >= g.n_vertices else v} "
                f"outside [0, {g.n_vertices})")
        loops = np.flatnonzero(g.edges[:, 0] == g.edges[:, 1])
        if loops.size:
            j = int(loops[0])
            raise GraphValidationError(
                f"graph {i}, edge {j}: self-loop at vertex "
                f"{int(g.edges[j, 0])}")
        # Graph.__post_init__ normalized endpoints to u < v, so exact
        # row duplicates are exactly duplicate undirected edges
        uniq, first, counts = np.unique(g.edges, axis=0,
                                        return_index=True,
                                        return_counts=True)
        if uniq.shape[0] != g.n_edges:
            j = int(first[counts > 1][0])
            u, v = (int(x) for x in g.edges[j])
            raise GraphValidationError(
                f"graph {i}, edge {j}: duplicate edge ({u}, {v}) — "
                f"{g.n_edges - uniq.shape[0]} repeated row(s)")


def encode_db(
    graphs: Sequence[Graph],
    *,
    pad_graphs: int | None = None,
    pad_vertices: int | None = None,
    pad_edges: int | None = None,
) -> GraphDB:
    """Pad/stack host graphs into a :class:`GraphDB`."""
    n = len(graphs)
    gpad = pad_graphs or n
    vpad = pad_vertices or max((g.n_vertices for g in graphs), default=1)
    epad = pad_edges or max((g.n_edges for g in graphs), default=1)
    vpad, epad = max(vpad, 1), max(epad, 1)
    if gpad < n:
        raise ValueError(f"pad_graphs={gpad} < {n} graphs")

    vlabels = -np.ones((gpad, vpad), dtype=np.int32)
    edges = np.zeros((gpad, epad, 2), dtype=np.int32)
    elabels = -np.ones((gpad, epad), dtype=np.int32)
    emask = np.zeros((gpad, epad), dtype=bool)
    for i, g in enumerate(graphs):
        if g.n_vertices > vpad or g.n_edges > epad:
            raise ValueError(
                f"graph {i} ({g.n_vertices}v,{g.n_edges}e) exceeds pad "
                f"({vpad}v,{epad}e)")
        vlabels[i, : g.n_vertices] = g.vlabels
        if g.n_edges:
            edges[i, : g.n_edges] = g.edges
            elabels[i, : g.n_edges] = g.elabels
            emask[i, : g.n_edges] = True
    return GraphDB(vlabels, edges, elabels, emask, n_graphs=n)


def decode_db(db: GraphDB) -> list[Graph]:
    out = []
    for i in range(db.n_graphs):
        nv = int((db.vlabels[i] >= 0).sum())
        keep = db.emask[i]
        out.append(Graph(db.vlabels[i, :nv], db.edges[i][keep], db.elabels[i][keep]))
    return out


# ---------------------------------------------------------------------------
# Synthetic dataset generators
# ---------------------------------------------------------------------------

def _random_connected_graph(
    rng: np.random.Generator,
    n_v: int,
    extra_edge_prob: float,
    n_vlabels: int,
    n_elabels: int,
) -> Graph:
    """Random connected graph: random spanning tree + Bernoulli extra edges."""
    vlabels = rng.integers(0, n_vlabels, size=n_v)
    edge_set: set[tuple[int, int]] = set()
    # random spanning tree (random attachment)
    order = rng.permutation(n_v)
    for idx in range(1, n_v):
        u = int(order[idx])
        v = int(order[rng.integers(0, idx)])
        edge_set.add((min(u, v), max(u, v)))
    # extra edges
    if n_v >= 3 and extra_edge_prob > 0:
        n_try = int(extra_edge_prob * n_v)
        for _ in range(n_try):
            u, v = rng.integers(0, n_v, size=2)
            if u != v:
                edge_set.add((min(int(u), int(v)), max(int(u), int(v))))
    edges = np.array(sorted(edge_set), dtype=np.int32).reshape(-1, 2)
    elabels = rng.integers(0, n_elabels, size=edges.shape[0])
    return Graph(vlabels, edges, elabels)


def random_db(
    n_graphs: int,
    *,
    n_vertices: int = 10,
    vertex_jitter: int = 3,
    extra_edge_prob: float = 0.3,
    n_vlabels: int = 5,
    n_elabels: int = 2,
    seed: int = 0,
) -> list[Graph]:
    """Random transaction DB; Graphgen-style knobs (|V|, density, labels)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        nv = int(np.clip(n_vertices + rng.integers(-vertex_jitter, vertex_jitter + 1), 2, None))
        out.append(_random_connected_graph(rng, nv, extra_edge_prob, n_vlabels, n_elabels))
    return out


def pubchem_like_db(n_graphs: int, *, seed: int = 0,
                    avg_edges: float = 28.0) -> list[Graph]:
    """Molecule-like DB matching the paper's Table I statistics:
    ~25-30 edges/graph, small label alphabet (atoms/bonds), sparse
    near-tree topology (rings via a few extra edges).
    """
    rng = np.random.default_rng(seed)
    out = []
    # ~atom alphabet: C,N,O,S,P,halogens... ; bonds: single/double/triple
    n_vlabels, n_elabels = 8, 3
    for _ in range(n_graphs):
        n_e_target = max(3, int(rng.normal(avg_edges, 4.0)))
        n_v = max(3, int(n_e_target * 0.92))  # near-tree: |E| slightly > |V|-1
        g = _random_connected_graph(rng, n_v, 0.12, n_vlabels, n_elabels)
        # skew vertex labels toward "carbon"
        skew = rng.random(g.n_vertices) < 0.6
        g.vlabels[skew] = 0
        out.append(g)
    return out


def paper_toy_db() -> list[Graph]:
    """The 3-graph toy database of paper Fig. 1(a).

    Labels: A=0, B=1, C=2, D=3, E=4.  Edges unlabeled (label 0).
    G1: A-B, B-C, B-D, C-D          (vertices 1:A 2:B 3:C 4:D)
    G2: A-B, B-C, B-D, B-E, D-E     (1:A 2:B 3:D 4:C 5:E  per Fig.)
    G3: B-D, B-E, D-E               (1:D 2:B 3:E)

    Mined with minsup=2 this yields the 13 frequent subgraphs of Fig. 1(b).
    """
    A, B, C, D, E = range(5)
    g1 = Graph([A, B, C, D], [(0, 1), (1, 2), (1, 3), (2, 3)], [0, 0, 0, 0])
    g2 = Graph([A, B, D, C, E], [(0, 1), (1, 3), (1, 2), (1, 4), (2, 4)],
               [0, 0, 0, 0, 0])
    g3 = Graph([D, B, E], [(0, 1), (1, 2), (0, 2)], [0, 0, 0])
    return [g1, g2, g3]
