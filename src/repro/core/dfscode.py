"""DFS codes and min-dfs-code canonical labeling (paper §IV-A.2).

MIRAGE adopts gSpan's canonical coding scheme: a pattern's edges are
serialized as 5-tuples ``(i, j, l_i, l_e, l_j)`` where ``i, j`` are DFS
discovery ids, and the lexicographically smallest valid DFS serialization
(the *min-dfs-code*) is the pattern's canonical key.  A candidate
generation path is valid iff the insertion order of its edges equals the
min-dfs-code edge order — this is the isomorphism_checking() of the
paper's mapper (Fig. 7, line 3) and what makes the algorithm complete
*without duplicates* (the concrete failure of Hill et al. [32]).

Pattern graphs are tiny (≤ ~15 edges in practice), so this module is exact
host-side Python/numpy.  The data-scale work (support counting over the
partitioned database) lives on-device in ``embedding.py`` / ``kernels/``.

Edge order (gSpan, Yan & Han 2002, DFS lexicographic order) for
``e1 = (i1, j1)``, ``e2 = (i2, j2)``:

  * both forward (i < j):  e1 < e2  iff  j1 < j2, or (j1 == j2 and i1 > i2)
  * both backward (i > j): e1 < e2  iff  i1 < i2, or (i1 == i2 and j1 < j2)
  * e1 backward, e2 forward: e1 < e2  iff  i1 < j2
  * e1 forward, e2 backward: e1 < e2  iff  j1 <= i2

with ties broken by the label triple ``(l_i, l_e, l_j)``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from .graphdb import Graph

# A code edge is a 5-tuple of ints: (i, j, l_i, l_e, l_j)
Edge5 = tuple[int, int, int, int, int]
Code = tuple[Edge5, ...]

__all__ = [
    "Edge5",
    "Code",
    "edge_lt",
    "code_lt",
    "code_to_graph",
    "min_dfs_code",
    "is_canonical",
    "rightmost_path",
    "code_to_array",
    "array_to_code",
]


def _is_forward(e: Edge5) -> bool:
    return e[0] < e[1]


def edge_lt(a: Edge5, b: Edge5) -> bool:
    """gSpan DFS-lexicographic edge order ``a < b`` (strict)."""
    ia, ja = a[0], a[1]
    ib, jb = b[0], b[1]
    fa, fb = ia < ja, ib < jb
    if fa and fb:
        if (ja, -ia) != (jb, -ib):
            return (ja, -ia) < (jb, -ib)
    elif (not fa) and (not fb):
        if (ia, ja) != (ib, jb):
            return (ia, ja) < (ib, jb)
    elif (not fa) and fb:      # backward vs forward
        return ia < jb
    else:                      # forward vs backward
        return ja <= ib
    # identical (i, j) structure -> label order
    return a[2:] < b[2:]


def code_lt(a: Code, b: Code) -> bool:
    """Strict DFS-lexicographic order on whole codes (prefix-aware)."""
    for ea, eb in zip(a, b):
        if ea == eb:
            continue
        return edge_lt(ea, eb)
    return len(a) < len(b)


def code_to_graph(code: Code) -> Graph:
    """Materialize the pattern graph of a DFS code (dense 0-based ids)."""
    n_v = max(max(e[0], e[1]) for e in code) + 1
    vlabels = -np.ones(n_v, dtype=np.int32)
    edges, elabels = [], []
    for (i, j, li, le, lj) in code:
        vlabels[i] = li
        vlabels[j] = lj
        edges.append((min(i, j), max(i, j)))
        elabels.append(le)
    assert (vlabels >= 0).all(), f"disconnected code {code}"
    return Graph(vlabels, np.array(edges, np.int32), np.array(elabels, np.int32))


@dataclasses.dataclass
class _State:
    """One partial DFS traversal of the pattern graph."""

    g2d: dict[int, int]          # graph vid -> dfs id
    d2g: list[int]               # dfs id -> graph vid
    used: frozenset[int]         # used (undirected) edge indices
    rmp: tuple[int, ...]         # rightmost path, as dfs ids root..rightmost


def _edge_index(g: Graph) -> dict[tuple[int, int], list[int]]:
    idx: dict[tuple[int, int], list[int]] = {}
    for k, (u, v) in enumerate(map(tuple, g.edges)):
        idx.setdefault((u, v), []).append(k)
        idx.setdefault((v, u), []).append(k)
    return idx


def min_dfs_code(
    g: Graph,
    bound: Optional[Code] = None,
) -> Optional[Code]:
    """Exact min-dfs-code of ``g`` by breadth-parallel minimal extension.

    Maintains *all* partial DFS traversals that realize the current minimal
    code prefix; at each step enumerates every legal gSpan extension
    (backward from the rightmost vertex, then forward from rightmost-path
    vertices), keeps the minimal edge tuple, and prunes states.

    If ``bound`` is given, returns ``None`` as soon as the minimal code is
    provably *smaller* than ``bound`` at some position (early exit for
    canonicality checking: a non-None result equal to bound ⇒ canonical).
    """
    if g.n_edges == 0:
        raise ValueError("empty pattern")
    adj: dict[int, list[tuple[int, int, int]]] = {}  # u -> [(v, elabel, eidx)]
    for k, ((u, v), el) in enumerate(zip(map(tuple, g.edges), g.elabels)):
        adj.setdefault(int(u), []).append((int(v), int(el), k))
        adj.setdefault(int(v), []).append((int(u), int(el), k))

    vl = g.vlabels

    # --- initial edge: minimal (l_u, l_e, l_v) over all orientations
    best0: Optional[Edge5] = None
    inits: list[tuple[Edge5, int, int, int]] = []
    for k, ((u, v), el) in enumerate(zip(map(tuple, g.edges), g.elabels)):
        for a, b in ((int(u), int(v)), (int(v), int(u))):
            t: Edge5 = (0, 1, int(vl[a]), int(el), int(vl[b]))
            inits.append((t, a, b, k))
            if best0 is None or t[2:] < best0[2:]:
                best0 = t
    assert best0 is not None
    code: list[Edge5] = [best0]
    if bound is not None and code[0] != bound[0]:
        # min first edge differs from bound's: it can only be smaller.
        return None
    states = [
        _State({a: 0, b: 1}, [a, b], frozenset([k]), (0, 1))
        for (t, a, b, k) in inits
        if t == best0
    ]

    n_edges = g.n_edges
    while len(code) < n_edges:
        best: Optional[Edge5] = None
        nexts: list[tuple[Edge5, _State]] = []
        for st in states:
            rm_dfs = st.rmp[-1]
            rm_g = st.d2g[rm_dfs]
            # backward extensions: rightmost vertex -> rightmost-path vertex
            # (never the immediate parent; edge must exist and be unused)
            for (nbr, el, k) in adj[rm_g]:
                if k in st.used or nbr not in st.g2d:
                    continue
                jd = st.g2d[nbr]
                # target must be a strict ancestor (on RMP, not rightmost
                # itself); the parent edge is already in `used` and the
                # graph is simple, so the no-multigraph rule holds.
                if jd not in st.rmp[:-1]:
                    continue
                t = (rm_dfs, jd, int(vl[rm_g]), el, int(vl[nbr]))
                nexts.append((t, _ext_backward(st, k)))
                if best is None or edge_lt(t, best):
                    best = t
            # forward extensions: from rightmost-path vertices to new vertices
            for pos in range(len(st.rmp) - 1, -1, -1):
                wd = st.rmp[pos]
                wg = st.d2g[wd]
                for (nbr, el, k) in adj[wg]:
                    if k in st.used or nbr in st.g2d:
                        continue
                    nd = len(st.d2g)
                    t = (wd, nd, int(vl[wg]), el, int(vl[nbr]))
                    nexts.append((t, _ext_forward(st, k, nbr, wd)))
                    if best is None or edge_lt(t, best):
                        best = t
        assert best is not None, "graph must be connected"
        pos = len(code)
        code.append(best)
        if bound is not None:
            if best != bound[pos]:
                # best < bound[pos] (bound is realizable, so min <= bound)
                return None
        states = [st for (t, st) in nexts if t == best]
    return tuple(code)


def _ext_backward(st: _State, eidx: int) -> _State:
    return _State(st.g2d, st.d2g, st.used | {eidx}, st.rmp)


def _ext_forward(st: _State, eidx: int, nbr_g: int, from_dfs: int) -> _State:
    nd = len(st.d2g)
    g2d = dict(st.g2d)
    g2d[nbr_g] = nd
    d2g = st.d2g + [nbr_g]
    # new rightmost path: truncate at the extension stub, append new vertex
    cut = st.rmp.index(from_dfs) + 1
    rmp = st.rmp[:cut] + (nd,)
    return _State(g2d, d2g, frozenset(st.used | {eidx}), rmp)


def is_canonical(code: Code) -> bool:
    """True iff ``code`` equals the min-dfs-code of its own pattern graph.

    This is exactly the mapper's isomorphism_checking() (paper Fig. 7
    line 3): of all generation paths of a pattern, only the one matching
    the min-dfs-code survives.
    """
    return min_dfs_code(code_to_graph(code), bound=code) == code


def rightmost_path(code: Code) -> tuple[int, ...]:
    """Rightmost path of a (valid) DFS code, as dfs ids root..rightmost."""
    parent: dict[int, int] = {}
    max_id = 0
    for (i, j, *_l) in code:
        if i < j:  # forward edge
            parent[j] = i
            max_id = max(max_id, j)
    path = [max_id]
    while path[-1] != 0:
        path.append(parent[path[-1]])
    return tuple(reversed(path))


# ---------------------------------------------------------------------------
# Fixed-shape array interop (device representation of pattern metadata)
# ---------------------------------------------------------------------------

def code_to_array(code: Code, max_edges: int) -> np.ndarray:
    """Pack a code into a (max_edges, 5) int32 array, -1 padded."""
    a = -np.ones((max_edges, 5), dtype=np.int32)
    if len(code) > max_edges:
        raise ValueError(f"code of size {len(code)} exceeds max_edges={max_edges}")
    for r, e in enumerate(code):
        a[r] = e
    return a


def array_to_code(a: np.ndarray) -> Code:
    out = []
    for row in np.asarray(a):
        if row[0] < 0 and row[1] < 0:
            break
        out.append(tuple(int(x) for x in row))
    return tuple(out)  # type: ignore[return-value]
