"""DFS codes and min-dfs-code canonical labeling (paper §IV-A.2).

MIRAGE adopts gSpan's canonical coding scheme: a pattern's edges are
serialized as 5-tuples ``(i, j, l_i, l_e, l_j)`` where ``i, j`` are DFS
discovery ids, and the lexicographically smallest valid DFS serialization
(the *min-dfs-code*) is the pattern's canonical key.  A candidate
generation path is valid iff the insertion order of its edges equals the
min-dfs-code edge order — this is the isomorphism_checking() of the
paper's mapper (Fig. 7, line 3) and what makes the algorithm complete
*without duplicates* (the concrete failure of Hill et al. [32]).

Pattern graphs are tiny (≤ ~15 edges in practice), so this module is exact
host-side Python/numpy.  The data-scale work (support counting over the
partitioned database) lives on-device in ``embedding.py`` / ``kernels/``.

Edge order (gSpan, Yan & Han 2002, DFS lexicographic order) for
``e1 = (i1, j1)``, ``e2 = (i2, j2)``:

  * both forward (i < j):  e1 < e2  iff  j1 < j2, or (j1 == j2 and i1 > i2)
  * both backward (i > j): e1 < e2  iff  i1 < i2, or (i1 == i2 and j1 < j2)
  * e1 backward, e2 forward: e1 < e2  iff  i1 < j2
  * e1 forward, e2 backward: e1 < e2  iff  j1 <= i2

with ties broken by the label triple ``(l_i, l_e, l_j)``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graphdb import Graph

# A code edge is a 5-tuple of ints: (i, j, l_i, l_e, l_j)
Edge5 = tuple[int, int, int, int, int]
Code = tuple[Edge5, ...]

__all__ = [
    "Edge5",
    "Code",
    "edge_lt",
    "code_lt",
    "code_to_graph",
    "min_dfs_code",
    "is_canonical",
    "rightmost_path",
    "code_to_array",
    "array_to_code",
    "edge_struct_key",
    "code_array_vertex_labels",
    "code_array_rightmost_path",
    "min_dfs_canonical_array",
]


def _is_forward(e: Edge5) -> bool:
    return e[0] < e[1]


def edge_lt(a: Edge5, b: Edge5) -> bool:
    """gSpan DFS-lexicographic edge order ``a < b`` (strict)."""
    ia, ja = a[0], a[1]
    ib, jb = b[0], b[1]
    fa, fb = ia < ja, ib < jb
    if fa and fb:
        if (ja, -ia) != (jb, -ib):
            return (ja, -ia) < (jb, -ib)
    elif (not fa) and (not fb):
        if (ia, ja) != (ib, jb):
            return (ia, ja) < (ib, jb)
    elif (not fa) and fb:      # backward vs forward
        return ia < jb
    else:                      # forward vs backward
        return ja <= ib
    # identical (i, j) structure -> label order
    return a[2:] < b[2:]


def code_lt(a: Code, b: Code) -> bool:
    """Strict DFS-lexicographic order on whole codes (prefix-aware)."""
    for ea, eb in zip(a, b):
        if ea == eb:
            continue
        return edge_lt(ea, eb)
    return len(a) < len(b)


def code_to_graph(code: Code) -> Graph:
    """Materialize the pattern graph of a DFS code (dense 0-based ids)."""
    n_v = max(max(e[0], e[1]) for e in code) + 1
    vlabels = -np.ones(n_v, dtype=np.int32)
    edges, elabels = [], []
    for (i, j, li, le, lj) in code:
        vlabels[i] = li
        vlabels[j] = lj
        edges.append((min(i, j), max(i, j)))
        elabels.append(le)
    assert (vlabels >= 0).all(), f"disconnected code {code}"
    return Graph(vlabels, np.array(edges, np.int32), np.array(elabels, np.int32))


@dataclasses.dataclass
class _State:
    """One partial DFS traversal of the pattern graph."""

    g2d: dict[int, int]          # graph vid -> dfs id
    d2g: list[int]               # dfs id -> graph vid
    used: frozenset[int]         # used (undirected) edge indices
    rmp: tuple[int, ...]         # rightmost path, as dfs ids root..rightmost


def _edge_index(g: Graph) -> dict[tuple[int, int], list[int]]:
    idx: dict[tuple[int, int], list[int]] = {}
    for k, (u, v) in enumerate(map(tuple, g.edges)):
        idx.setdefault((u, v), []).append(k)
        idx.setdefault((v, u), []).append(k)
    return idx


def min_dfs_code(
    g: Graph,
    bound: Optional[Code] = None,
) -> Optional[Code]:
    """Exact min-dfs-code of ``g`` by breadth-parallel minimal extension.

    Maintains *all* partial DFS traversals that realize the current minimal
    code prefix; at each step enumerates every legal gSpan extension
    (backward from the rightmost vertex, then forward from rightmost-path
    vertices), keeps the minimal edge tuple, and prunes states.

    If ``bound`` is given, returns ``None`` as soon as the minimal code is
    provably *smaller* than ``bound`` at some position (early exit for
    canonicality checking: a non-None result equal to bound ⇒ canonical).
    """
    if g.n_edges == 0:
        raise ValueError("empty pattern")
    adj: dict[int, list[tuple[int, int, int]]] = {}  # u -> [(v, elabel, eidx)]
    for k, ((u, v), el) in enumerate(zip(map(tuple, g.edges), g.elabels)):
        adj.setdefault(int(u), []).append((int(v), int(el), k))
        adj.setdefault(int(v), []).append((int(u), int(el), k))

    vl = g.vlabels

    # --- initial edge: minimal (l_u, l_e, l_v) over all orientations
    best0: Optional[Edge5] = None
    inits: list[tuple[Edge5, int, int, int]] = []
    for k, ((u, v), el) in enumerate(zip(map(tuple, g.edges), g.elabels)):
        for a, b in ((int(u), int(v)), (int(v), int(u))):
            t: Edge5 = (0, 1, int(vl[a]), int(el), int(vl[b]))
            inits.append((t, a, b, k))
            if best0 is None or t[2:] < best0[2:]:
                best0 = t
    assert best0 is not None
    code: list[Edge5] = [best0]
    if bound is not None and code[0] != bound[0]:
        # min first edge differs from bound's: it can only be smaller.
        return None
    states = [
        _State({a: 0, b: 1}, [a, b], frozenset([k]), (0, 1))
        for (t, a, b, k) in inits
        if t == best0
    ]

    n_edges = g.n_edges
    while len(code) < n_edges:
        best: Optional[Edge5] = None
        nexts: list[tuple[Edge5, _State]] = []
        for st in states:
            rm_dfs = st.rmp[-1]
            rm_g = st.d2g[rm_dfs]
            # backward extensions: rightmost vertex -> rightmost-path vertex
            # (never the immediate parent; edge must exist and be unused)
            for (nbr, el, k) in adj[rm_g]:
                if k in st.used or nbr not in st.g2d:
                    continue
                jd = st.g2d[nbr]
                # target must be a strict ancestor (on RMP, not rightmost
                # itself); the parent edge is already in `used` and the
                # graph is simple, so the no-multigraph rule holds.
                if jd not in st.rmp[:-1]:
                    continue
                t = (rm_dfs, jd, int(vl[rm_g]), el, int(vl[nbr]))
                nexts.append((t, _ext_backward(st, k)))
                if best is None or edge_lt(t, best):
                    best = t
            # forward extensions: from rightmost-path vertices to new vertices
            for pos in range(len(st.rmp) - 1, -1, -1):
                wd = st.rmp[pos]
                wg = st.d2g[wd]
                for (nbr, el, k) in adj[wg]:
                    if k in st.used or nbr in st.g2d:
                        continue
                    nd = len(st.d2g)
                    t = (wd, nd, int(vl[wg]), el, int(vl[nbr]))
                    nexts.append((t, _ext_forward(st, k, nbr, wd)))
                    if best is None or edge_lt(t, best):
                        best = t
        assert best is not None, "graph must be connected"
        pos = len(code)
        code.append(best)
        if bound is not None:
            if best != bound[pos]:
                # best < bound[pos] (bound is realizable, so min <= bound)
                return None
        states = [st for (t, st) in nexts if t == best]
    return tuple(code)


def _ext_backward(st: _State, eidx: int) -> _State:
    return _State(st.g2d, st.d2g, st.used | {eidx}, st.rmp)


def _ext_forward(st: _State, eidx: int, nbr_g: int, from_dfs: int) -> _State:
    nd = len(st.d2g)
    g2d = dict(st.g2d)
    g2d[nbr_g] = nd
    d2g = st.d2g + [nbr_g]
    # new rightmost path: truncate at the extension stub, append new vertex
    cut = st.rmp.index(from_dfs) + 1
    rmp = st.rmp[:cut] + (nd,)
    return _State(g2d, d2g, frozenset(st.used | {eidx}), rmp)


def is_canonical(code: Code) -> bool:
    """True iff ``code`` equals the min-dfs-code of its own pattern graph.

    This is exactly the mapper's isomorphism_checking() (paper Fig. 7
    line 3): of all generation paths of a pattern, only the one matching
    the min-dfs-code survives.
    """
    return min_dfs_code(code_to_graph(code), bound=code) == code


def rightmost_path(code: Code) -> tuple[int, ...]:
    """Rightmost path of a (valid) DFS code, as dfs ids root..rightmost."""
    parent: dict[int, int] = {}
    max_id = 0
    for (i, j, *_l) in code:
        if i < j:  # forward edge
            parent[j] = i
            max_id = max(max_id, j)
    path = [max_id]
    while path[-1] != 0:
        path.append(parent[path[-1]])
    return tuple(reversed(path))


# ---------------------------------------------------------------------------
# Fixed-shape array interop (device representation of pattern metadata)
# ---------------------------------------------------------------------------

def code_to_array(code: Code, max_edges: int) -> np.ndarray:
    """Pack a code into a (max_edges, 5) int32 array, -1 padded."""
    a = -np.ones((max_edges, 5), dtype=np.int32)
    if len(code) > max_edges:
        raise ValueError(f"code of size {len(code)} exceeds max_edges={max_edges}")
    for r, e in enumerate(code):
        a[r] = e
    return a


def array_to_code(a: np.ndarray) -> Code:
    out = []
    for row in np.asarray(a):
        if row[0] < 0 and row[1] < 0:
            break
        out.append(tuple(int(x) for x in row))
    return tuple(out)  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Device-side DFS-code ops (pipeline="device_loop", DESIGN.md §13)
#
# The host `edge_lt` / `min_dfs_code` machinery above re-expressed as
# fixed-shape jnp programs so candidate generation can run inside the
# whole-run `lax.while_loop`.  Codes travel as (L, 5) int32 arrays,
# -1 padded (``code_to_array`` layout).
# ---------------------------------------------------------------------------

_BIG = np.int32(1 << 29)  # lexicographic sentinel (labels/keys are << this)


def edge_struct_key(i, j, nv: int):
    """Linearize `edge_lt`'s structural (i, j) comparison into one int key.

    forward  (i < j): key = (2j)   * (nv+1) + (nv - i)   — orders by (j, -i)
    backward (i > j): key = (2i+1) * (nv+1) + j          — orders by (i, j)

    The parity of the leading coefficient resolves the mixed cases exactly:
    backward(i1,·) < forward(·,j2) iff 2·i1+1 < 2·j2 iff i1 < j2, and
    forward(·,j1) < backward(i2,·) iff 2·j1 < 2·i2+1 iff j1 <= i2 — the
    four `edge_lt` structural rules.  Label triples break the remaining
    ties separately (see `min_dfs_canonical_array`'s masked lex-min).
    """
    fwd = i < j
    return jnp.where(fwd, (2 * j) * (nv + 1) + (nv - i),
                     (2 * i + 1) * (nv + 1) + j).astype(jnp.int32)


def _lex_min(mask, comps):
    """Masked lexicographic min over broadcastable int components.

    Returns ([min components], achiever-mask); mask must have the full
    broadcast shape."""
    best = []
    for c in comps:
        m = jnp.min(jnp.where(mask, c, _BIG))
        mask = mask & (c == m)
        best.append(m)
    return best, mask


def code_array_vertex_labels(code, n_vertex_slots: int):
    """(L,5) code array -> (NV,) vertex labels, -1 on unused slots."""
    NV = n_vertex_slots
    valid = code[:, 0] >= 0
    vl = jnp.full((NV,), -1, jnp.int32)
    vl = vl.at[jnp.where(valid, code[:, 0], NV)].set(code[:, 2], mode="drop")
    vl = vl.at[jnp.where(valid, code[:, 1], NV)].set(code[:, 4], mode="drop")
    return vl


def _dfs_parents(code, n_vertex_slots: int, row_mask):
    """parent[j] = i over forward rows selected by ``row_mask``."""
    NV = n_vertex_slots
    fwd = row_mask & (code[:, 0] < code[:, 1]) & (code[:, 0] >= 0)
    par = jnp.full((NV,), -1, jnp.int32)
    return par.at[jnp.where(fwd, code[:, 1], NV)].set(code[:, 0], mode="drop")


def code_array_rightmost_path(code, n_vertex_slots: int):
    """(L,5) code array -> (rmp (NV,) root-first -1-padded, rmp_len, n_v).

    Array twin of `rightmost_path`: walk the forward-edge parent chain
    from the rightmost (max dfs id) vertex to the root.
    """
    NV = n_vertex_slots
    L = code.shape[0]
    valid = code[:, 0] >= 0
    n_v = jnp.max(jnp.where(valid, jnp.maximum(code[:, 0], code[:, 1]), -1)) + 1
    par = _dfs_parents(code, NV, jnp.ones((L,), bool))
    rm = n_v - 1

    def up(s, carry):
        cur, rev = carry
        rev = rev.at[s].set(cur)
        nxt = jnp.where(cur > 0, par[jnp.clip(cur, 0, NV - 1)], -1)
        return nxt, rev

    _, rev = jax.lax.fori_loop(0, NV, up, (rm, jnp.full((NV,), -1, jnp.int32)))
    rmp_len = (rev >= 0).sum()
    idx = rmp_len - 1 - jnp.arange(NV)
    rmp = jnp.where(idx >= 0, rev[jnp.clip(idx, 0, NV - 1)], -1)
    return rmp, rmp_len, n_v


def _onpath_mask(par, rm, n_vertex_slots: int):
    """(NV,) bool: dfs ids on the rightmost path (root..rm inclusive)."""
    NV = n_vertex_slots
    cols = jnp.arange(NV)

    def wstep(s, carry):
        cur, onp = carry
        onp = onp | ((cols == cur) & (cur >= 0))
        return jnp.where(cur > 0, par[jnp.clip(cur, 0, NV - 1)], -1), onp

    _, onpath = jax.lax.fori_loop(0, NV, wstep, (rm, jnp.zeros((NV,), bool)))
    return onpath


def min_dfs_canonical_array(code, *, n_vertex_slots: int, max_states: int):
    """Array twin of `is_canonical`: (canonical, overflow) bool scalars.

    Runs the breadth-parallel minimal-extension machine of `min_dfs_code`
    under a fixed state budget: all partial traversals realizing the
    minimal prefix live in ``max_states`` slots of (graph->dfs, dfs->graph,
    used-edge-bitmask) arrays.  The dfs-side quantities (vertex count,
    rightmost path) are shared across states — they are functions of the
    code prefix alone — so only the graph-side mappings are per-state.

    If the live state set ever exceeds ``max_states`` the result is
    unreliable and ``overflow`` is set — callers must fall back to the
    host `is_canonical` (the driver bails the whole device loop).
    Vmappable over a batch of codes; requires L < 32 (int32 edge bitmask).
    """
    L = code.shape[0]
    NV = n_vertex_slots
    MS = max_states
    if L >= 32:
        raise ValueError(f"max_edges={L} exceeds the int32 edge-bitmask width")
    ar_l = jnp.arange(L)
    cols = jnp.arange(NV)

    i_, j_ = code[:, 0], code[:, 1]
    li_, le_, lj_ = code[:, 2], code[:, 3], code[:, 4]
    valid_e = i_ >= 0
    ne = valid_e.sum()
    vl = code_array_vertex_labels(code, NV)

    # directed orientation table (2L,): first L rows umin->umax, then flipped
    umin, umax = jnp.minimum(i_, j_), jnp.maximum(i_, j_)
    du = jnp.concatenate([umin, umax])
    dv = jnp.concatenate([umax, umin])
    de = jnp.concatenate([le_, le_])
    dk = jnp.concatenate([ar_l, ar_l]).astype(jnp.int32)
    dvalid = jnp.concatenate([valid_e, valid_e])
    dlu = vl[jnp.clip(du, 0, NV - 1)]
    dlv = vl[jnp.clip(dv, 0, NV - 1)]

    # --- initial edge: minimal (l_u, l_e, l_v) over valid orientations
    (b0l, b0e, b0r), m0 = _lex_min(dvalid, (dlu, de, dlv))
    ok0 = (b0l == li_[0]) & (b0e == le_[0]) & (b0r == lj_[0])

    pos0 = jnp.cumsum(m0) - 1
    dest0 = jnp.where(m0, pos0, MS)
    src_o = jnp.zeros((MS,), jnp.int32).at[dest0].set(
        jnp.arange(2 * L, dtype=jnp.int32), mode="drop")
    alive = jnp.arange(MS) < m0.sum()
    su, sv, sk = du[src_o], dv[src_o], dk[src_o]
    g2d = jnp.where(cols[None, :] == su[:, None], 0,
                    jnp.where(cols[None, :] == sv[:, None], 1, -1))
    d2g = jnp.where(cols[None, :] == 0, su[:, None],
                    jnp.where(cols[None, :] == 1, sv[:, None], -1))
    used = jnp.where(alive, jnp.int32(1) << sk, 0)

    fwd_rows = valid_e & (i_ < j_)

    def step(t, carry):
        g2d, d2g, used, alive, result, done, ovf = carry
        act = (~done) & (t < ne)
        # shared dfs-space prefix quantities (rows [0, t) are consumed)
        pre = ar_l < t
        nmap = 1 + jnp.sum(fwd_rows & pre)
        rm = nmap - 1
        par = _dfs_parents(code, NV, pre)
        onpath = _onpath_mask(par, rm, NV)

        # extension slots: (state, orientation) -> candidate edge
        fu = g2d[:, jnp.clip(du, 0, NV - 1)]      # (MS, 2L) dfs id of u
        fv = g2d[:, jnp.clip(dv, 0, NV - 1)]
        unused = ((used[:, None] >> dk[None, :]) & 1) == 0
        base = alive[:, None] & dvalid[None, :] & unused
        is_b = (fu == rm) & (fv >= 0)
        okb = base & is_b & (fv != rm) & onpath[jnp.clip(fv, 0, NV - 1)]
        is_f = (fv < 0) & (fu >= 0)
        okf = base & is_f & onpath[jnp.clip(fu, 0, NV - 1)]
        okx = okb | okf
        ei = jnp.where(is_b, rm, fu)
        ej = jnp.where(is_b, fv, nmap)
        skey = edge_struct_key(ei, ej, NV)

        shape2 = (MS, 2 * L)
        (bk_, bl1, bl2, bl3), mbest = _lex_min(
            okx, (skey,
                  jnp.broadcast_to(dlu, shape2),
                  jnp.broadcast_to(de, shape2),
                  jnp.broadcast_to(dlv, shape2)))
        bkey_t = edge_struct_key(i_[t], j_[t], NV)
        match = ((bk_ == bkey_t) & (bl1 == li_[t]) & (bl2 == le_[t])
                 & (bl3 == lj_[t]) & mbest.any())

        # compact achiever (state, orientation) pairs into the state slots
        flat = mbest.reshape(-1)
        posn = jnp.cumsum(flat) - 1
        nn = flat.sum()
        dest = jnp.where(flat, posn, MS)
        sidx = jnp.zeros((MS,), jnp.int32).at[dest].set(
            jnp.arange(MS * 2 * L, dtype=jnp.int32), mode="drop")
        s_sel = sidx // (2 * L)
        o_sel = sidx % (2 * L)
        isf_sel = okf.reshape(-1)[sidx]
        gv = dv[jnp.clip(o_sel, 0, 2 * L - 1)]
        ng2d = jnp.where((cols[None, :] == gv[:, None]) & isf_sel[:, None],
                         nmap, g2d[s_sel])
        nd2g = jnp.where((cols[None, :] == nmap) & isf_sel[:, None],
                         gv[:, None], d2g[s_sel])
        nused = used[s_sel] | (jnp.int32(1) << dk[jnp.clip(o_sel, 0, 2 * L - 1)])
        nalive = jnp.arange(MS) < jnp.minimum(nn, MS)

        g2d = jnp.where(act, ng2d, g2d)
        d2g = jnp.where(act, nd2g, d2g)
        used = jnp.where(act, nused, used)
        alive = jnp.where(act, nalive, alive)
        result = result & jnp.where(act, match, True)
        done = done | (act & ~match)
        ovf = ovf | (act & (nn > MS))
        return g2d, d2g, used, alive, result, done, ovf

    ovf0 = m0.sum() > MS
    init = (g2d, d2g, used, alive, ok0, ~ok0, ovf0)
    if L > 1:
        _, _, _, _, result, _, ovf = jax.lax.fori_loop(1, L, step, init)
    else:
        result, ovf = ok0, ovf0
    return result, ovf
