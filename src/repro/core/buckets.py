"""Shape bucketing for the level program (DESIGN.md §9).

The single-sync pipeline compiles ONE program per level — but a fresh
one every level, because the candidate count C, survivor cap S, parent
store width P, embedding cap M, vertex-slot width K and the fused
schedule's row count all change shape between iterations.  Deep mining
runs therefore pay XLA compile latency per level: exactly the
per-iteration startup overhead the paper's iterative-MapReduce framing
warns about (§IV-B), reincarnated as jit tracing.

The fix is the classic one from distributed FSM systems (DIMSpan keeps
the per-iteration dataflow program fixed while only data volume
changes): round every dynamic shape UP to a small geometric family —
``floor · 2^i`` — and mask the padded tail end-to-end.  Consecutive
levels then present identical shapes to ``jax.jit`` and hit its cache;
a whole mining run compiles a handful of programs instead of one per
level, and because the (S, M, K)-bucketed parent and child stores have
IDENTICAL shapes, buffer donation degenerates into a real arena: XLA
aliases the donated parent store's pages for the child store instead of
merely freeing them at program exit.

Masking contract (who neutralizes which padded slots):

  C / Cp  padded candidate rows — excluded by the wire's ``real`` mask
          (verdicts, survivor compaction, cost signal) and sliced off by
          ``unpack_wire``; the fused schedule marks them ``valid=0`` so
          they contribute zero support.
  S       padded survivor slots — ``valid_s`` cond-gates pass-2 into a
          constant fill; their masks are all-False downstream.
  P       padded parent slots — never referenced (candidate ``parent``
          indices only address real patterns); masks all-False.
  M       padded embedding rows — mask=False, PAD(-1) vertex entries.
  K       padded vertex slots — PAD(-1); the join's stub/to one-hots
          never select them and the forward-membership test cannot
          match them (real vertex ids are >= 0).
"""
from __future__ import annotations

import dataclasses

__all__ = ["BucketSpec", "bucket_size", "round_up_multiple"]


def bucket_size(x: int, floor: int) -> int:
    """Smallest member of the geometric family {floor · 2^i} >= x."""
    if floor < 1:
        raise ValueError(f"bucket floor must be >= 1, got {floor}")
    n = floor
    while n < x:
        n *= 2
    return n


def round_up_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """The per-run bucket family (from ``MirageConfig``).

    ``c_floor`` governs the padded candidate axis Cp (and the fused
    schedule's row bucket), ``s_floor`` the survivor cap S and the
    parent-store pattern axis P, ``k_floor`` the OL vertex-slot axis.
    The embedding axis M needs no floor of its own: its family is
    anchored at the (power-of-two) ``max_embeddings`` cap, which the
    escalation valve already walks by doubling.
    """

    c_floor: int = 64
    s_floor: int = 32
    k_floor: int = 8

    def candidates(self, c: int, n_workers: int) -> int:
        """Cp: bucket, then keep the divisibility contract Cp % W == 0
        (a no-op for power-of-two W) that both the reduce_scatter
        shuffle (tiled psum_scatter) and the SHARDED level wire — each
        worker packs exactly a Cp/W support slice, DESIGN.md §11 —
        rely on."""
        return round_up_multiple(bucket_size(c, self.c_floor), n_workers)

    def survivors(self, s: int, ceiling: int) -> int:
        """S (and the parent axis P): bucket, clamp at the (already
        bucketed) Cp ceiling so a cap miss retries into the NEXT family
        member instead of thrashing between adjacent predictions."""
        return min(ceiling, bucket_size(s, self.s_floor))

    def vertex_slots(self, k: int, parent_k: int | None = None) -> int:
        """K: reuse the parent store's (bucketed) width while the child
        pattern still fits — the store only grows at family boundaries,
        so consecutive levels alias the same arena shape."""
        if parent_k is not None and k <= parent_k:
            return parent_k
        return bucket_size(k, self.k_floor)

    def embeddings(self, m: int, anchor: int) -> int:
        """M family anchored at the configured cap (level-1 stores may
        need more than the cap to stay exact: M1 >= F)."""
        return bucket_size(m, bucket_size(anchor, 1))
