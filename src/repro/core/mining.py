"""MIRAGE iterative mining driver (paper §IV-B/C, Figs. 9-10).

Phases:
  1. data partition  — filter infrequent edges, split into NP partitions
                       (NP ≫ workers, paper Fig. 20), pad uniformly;
  2. preparation     — per-partition static structures (edge-OL,
                       edge-extension map is implied by the triple table)
                       + the level-1 pattern OLs;
  3. mining          — host enumerates canonical candidates from F_k
                       (tiny metadata); the devices run the whole level
                       as ONE program (`core/level_step.py`): fused join
                       (map), dense collective (shuffle+reduce), on-device
                       survivor compaction, child-OL materialization and
                       straggler repack — the host syncs exactly once per
                       level, on the packed wire vector.  Repeat until no
                       frequent patterns.

Three pipelines (MirageConfig.pipeline):
  "single_sync" — the device-resident level program above (default);
  "device_loop" — the ENTIRE run as one jitted lax.while_loop program
                  (core/device_loop.py, DESIGN.md §13): on-device
                  candidate generation + schedule + level compute, one
                  device→host transfer per run; bails to single_sync
                  when a static budget overflows;
  "legacy"      — the PR-1 two-program driver (separate support and
                  materialize dispatches, host keep-list, host-side
                  escalation loop and LPT detour), kept as the
                  differential oracle and benchmark baseline.

Fault tolerance: every level boundary checkpoints the complete mining
state (codes + OL store + cursor) atomically — the HDFS write of the
paper made explicit.  ``Mirage.fit(..., resume=True)`` replays at most
one level after any failure, and may resume onto a *different* mesh
(elastic: state is saved unsharded, resharded on load).

Straggler mitigation: the join kernel's embed-count output is an exact
per-partition cost signal for the *next* level; when predicted imbalance
exceeds a threshold the partition→device assignment is re-packed (LPT)
and the OL store re-laid-out (one all-to-all-equivalent gather).  Under
the single-sync pipeline both the decision and the gather run on device;
the applied permutation rides home in the wire so checkpoints stay in
canonical partition order.  This is deterministic load balancing,
replacing Hadoop's speculative execution.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import Backend, default_backend, is_fused_backend
from ..runtime import checkpoint as ckpt
from ..runtime import faults
from ..runtime.sharding import partition_sharding
from ..runtime.watchdog import Watchdog
from . import device_loop as dloop
from .auditor import Auditor
from .buckets import BucketSpec, bucket_size, round_up_multiple
from .candgen import (Candidate, EdgeAlphabet, candidates_from_arrays,
                      device_candgen_jit, filter_speculative,
                      generate_candidates, schedule_candidates)
from .dfscode import Code, array_to_code, code_to_array
from .embedding import build_edge_ol, candidate_meta, level1_ol
from .graphdb import Graph
from .level_step import _IMBAL_FX, dispatch_level, fetch_wire, permute_stores
from .mapreduce import MiningMesh, map_materialize, map_reduce_supports
from .partition import make_partitions

__all__ = ["MirageConfig", "LevelStats", "DistMiningResult",
           "PartialResult", "Mirage", "DonationPolicy",
           "DonationRetryRebuild", "decode_saved_levels"]

PIPELINES = ("single_sync", "device_loop", "legacy")
CANDGENS = ("host", "device")


class DonationRetryRebuild(RuntimeError):
    """An armed-donation level needed its retry path, but donation
    already consumed the parent buffers — the driver must rebuild them
    from the latest checkpoint and replay the level."""

    def __init__(self, level: int):
        self.level = level
        super().__init__(
            f"level {level}: donated arena hit a retry — rebuilding "
            f"parents from checkpoint")


class DonationPolicy:
    """Donation re-arming state machine (DESIGN.md §10, closing the
    PR-3 ROADMAP note).

    A level that might retry (survivor-cap miss, escalation valve) must
    normally keep its parent buffers alive — donation off, arena lost.
    This policy re-arms donation after ``k`` consecutive clean levels
    *provided* a checkpoint exists to rebuild the parents from: the
    retry stays possible, it just changes shape — a gambled retry costs
    one checkpoint load + level replay instead of a kept parent copy
    every level.  A retry or a rebuild resets the streak."""

    def __init__(self, k: int, can_rebuild: bool = False):
        self.k = k
        self.can_rebuild = can_rebuild
        self.clean_streak = 0
        self.rebuilds = 0

    @property
    def armed(self) -> bool:
        """May the driver donate even though this level could retry?"""
        return (self.k > 0 and self.can_rebuild
                and self.clean_streak >= self.k)

    def record(self, retried: bool) -> None:
        """Account one completed level."""
        self.clean_streak = 0 if retried else self.clean_streak + 1

    def record_rebuild(self) -> None:
        """The gamble lost: parents were rebuilt from checkpoint."""
        self.rebuilds += 1
        self.clean_streak = 0


@dataclasses.dataclass
class MirageConfig:
    minsup: float | int                 # fraction of |G| or absolute count
    n_partitions: int = 8
    scheme: int | str = 2               # partition scheme (1|2|"density")
    max_size: Optional[int] = None      # max pattern edges (None = to fixpoint)
    max_embeddings: int = 32            # M cap (exactness valve escalates)
    max_embeddings_limit: int = 512     # escalation ceiling
    max_occ: Optional[int] = None       # F pad (None = derive from data)
    backend: Optional[Backend] = None   # kernels backend (None = auto)
    # shuffle collective; None resolves per pipeline in __post_init__:
    # "reduce_scatter" for single_sync (fig19: faster AND lighter on the
    # wire), "psum" for legacy (the paper-faithful differential oracle)
    reduce: Optional[str] = None        # "psum" | "reduce_scatter" | None
    # sharded wire layout (DESIGN.md §11): each worker transfers only its
    # C/W support slice.  None = auto (on whenever the reduce_scatter
    # shuffle runs under single_sync — the slice already lives there)
    sharded_wire: Optional[bool] = None
    # bit-packed support path (DESIGN.md §12): verdict bitsets in VMEM
    # with AND+popcount support counting, bit-lane verdict gathers, and
    # a 2x-uint16 gsup wire slice.  None = auto (on for single_sync);
    # the legacy pipeline stays dense — it is the differential oracle.
    # Regardless of the flag, packing engages only when every support
    # fits uint16 (total graph count < 2^16)
    packed_support: Optional[bool] = None
    # double-buffer host candidate generation for level k+1 in the
    # shadow of level k's in-flight device program (DESIGN.md §11)
    overlap_candgen: bool = True
    # speculation cost gate: the speculative candgen runs over the FULL
    # candidate superset, |C_k|/|F_k| times the survivor-only work — at
    # sparse survival that dwarfs the device time it hides behind.  The
    # driver estimates its cost from a running per-parent candgen rate
    # and skips the speculation for any level where the estimate
    # exceeds the hiding window max(previous level's device seconds,
    # this floor)
    overlap_spec_window: float = 0.05
    checkpoint_dir: Optional[str] = None
    escalate_on_overflow: bool = True
    rebalance_threshold: float = 1.25   # max/mean partition cost trigger
    rebalance: bool = True
    pipeline: str = "single_sync"   # "single_sync"|"device_loop"|"legacy"
    # candidate generation: "host" (the python generator) or "device"
    # (candgen.device_candidates dispatched per level — the benchable
    # stepping stone toward device_loop, which always generates on
    # device INSIDE its while_loop).  Device candgen statically disables
    # the speculative-overlap machinery; a per-level budget/state
    # overflow falls back to the host generator for that level only.
    candgen: str = "host"
    # ---- device_loop static budgets (DESIGN.md §13) ------------------
    # canonical candidate budget CB per loop iteration (None = auto:
    # 4x the host-generated start-level candidate count, bucketed —
    # candgen typically peaks one or two levels past the start); the raw
    # structural-slot budget before canonicality filtering (None = auto:
    # 4x CB); the canonicality machine's bounded state count.  Any
    # overflow trips a bail flag and the run falls back to single_sync.
    device_c_budget: Optional[int] = None
    device_raw_budget: Optional[int] = None
    device_max_states: int = 64
    # checkpoint cadence: re-invoke the (single) compiled run program
    # every k levels, fetching wire + OL store at each boundary for the
    # canonical checkpoint (None = no mid-run checkpoints — exactly one
    # device→host transfer for the whole run)
    device_loop_ckpt_every: Optional[int] = None
    # > 0: replace the while_loop with this many cond-gated body
    # applications per program invocation (the unrolled stepping stone)
    device_loop_unroll: int = 0
    donate: bool = True                 # donate OL buffers when retry-free
    # re-arm donation after this many consecutive clean levels even when
    # a retry is possible, rebuilding parents from checkpoint if the
    # gamble loses (0 disables; needs checkpoint_dir to ever engage)
    donation_rearm_levels: int = 3
    predict_survivors: bool = True      # shrink the survivor cap from history
    survivor_slack: float = 2.0         # cap = slack * predicted survivors
    # ---- shape bucketing (single_sync pipeline; DESIGN.md §9) --------
    # round the per-level shapes (Cp, S, P, M, K, fused-schedule rows)
    # up to the geometric family floor·2^i so consecutive levels hit the
    # jit cache instead of recompiling, and the donated parent/child
    # stores alias as one arena.  Padded slots are masked end-to-end.
    bucket_shapes: bool = True
    bucket_c_floor: int = 64            # candidate axis Cp (+ sched rows)
    bucket_s_floor: int = 32            # survivor cap S / parent axis P
    bucket_k_floor: int = 8             # OL vertex-slot axis K
    # ---- continuous invariant auditor + deadlines (DESIGN.md §14) ----
    # device audit word folded into the wire (monotonicity, compaction,
    # range, survivor-count) + sampled host spot checks each level
    # (downward closure, DFS-code canonicality); violations raise
    # AuditError, a state-class fault the supervisor heals by replay
    audit: bool = True
    audit_samples: int = 2              # host spot checks per level
    # watchdog phase-deadline policy: deadline = max(floor, slack·EWMA)
    # of recent level wall-times; floor=0 with no EWMA sample = unarmed
    # (the first level usually contains compilation)
    level_deadline_floor: float = 0.0
    level_deadline_slack: float = 8.0

    def __post_init__(self):
        if self.pipeline not in PIPELINES:
            raise ValueError(f"pipeline={self.pipeline!r} must be one of "
                             f"{PIPELINES}")
        if self.candgen not in CANDGENS:
            raise ValueError(f"candgen={self.candgen!r} must be one of "
                             f"{CANDGENS}")
        if self.n_partitions < 1:
            raise ValueError(
                f"n_partitions={self.n_partitions} must be >= 1")
        if self.reduce is None:
            self.reduce = ("psum" if self.pipeline == "legacy"
                           else "reduce_scatter")
        if self.reduce not in ("psum", "reduce_scatter"):
            raise ValueError(f"reduce={self.reduce!r} must be 'psum' or "
                             f"'reduce_scatter'")
        if self.packed_support and self.pipeline == "legacy":
            raise ValueError(
                "packed_support=True is unavailable on pipeline='legacy' — "
                "the legacy pipeline stays dense as the differential oracle")
        if self.pipeline == "device_loop":
            if self.max_size is None:
                raise ValueError(
                    "pipeline='device_loop' needs a finite max_size — the "
                    "while_loop carry (codes, OL store, run outputs) is "
                    "shaped by the run's maximum pattern size")
            if not self.bucket_shapes:
                raise ValueError(
                    "pipeline='device_loop' requires bucket_shapes=True — "
                    "its static budgets are sized in the bucket families")
            if not self.escalate_on_overflow:
                raise ValueError(
                    "pipeline='device_loop' requires escalate_on_overflow "
                    "— the loop mines at one uniform M and reruns doubled "
                    "on overflow, matching only the exact (escalated) "
                    "host semantics")
        if self.level_deadline_slack < 1.0:
            raise ValueError(
                f"level_deadline_slack={self.level_deadline_slack} must "
                f"be >= 1 — a sub-unit slack trips on every level")
        if self.pipeline == "device_loop" or self.candgen == "device":
            # device candgen makes host speculation structurally
            # impossible mid-loop — disable it statically (satellite:
            # the cost gate is bypassed, no PendingLevel speculation)
            self.overlap_candgen = False


@dataclasses.dataclass
class LevelStats:
    level: int
    n_candidates: int
    n_frequent: int
    overflow: int
    seconds: float
    map_seconds: float
    rebalanced: bool
    imbalance: float                    # max/mean partition embed-count
    escalations: int = 0                # M-cap doublings the valve performed
    # host candgen seconds for the NEXT level, spent in the shadow of
    # this level's in-flight device program (0.0 when not overlapped)
    candgen_seconds: float = 0.0
    survivor_cap: int = 0               # S the level program compacted into
    retried: bool = False               # level took a materialize-only retry


@dataclasses.dataclass
class DistMiningResult:
    levels: list[list[Code]]
    supports: dict[Code, int]
    stats: list[LevelStats]
    alphabet: EdgeAlphabet
    minsup: int
    total_overflow: int

    @property
    def frequent(self) -> dict[Code, int]:
        return self.supports

    def counts(self) -> list[int]:
        return [len(l) for l in self.levels]


@dataclasses.dataclass
class PartialResult:
    """A verified *prefix* of the full answer (anytime contract, §14).

    MIRAGE's level-synchronous loop makes every completed level a
    complete, valid answer to "all frequent subgraphs up to size k" —
    so when the supervisor's retry budget, degradation ladder, or run
    deadline is exhausted, it cuts here: the frequent set through the
    newest intact *audited* checkpoint, re-verified by
    :func:`~repro.core.auditor.audit_frequent_set` before it is
    trusted.  ``complete`` is always False (the marker callers branch
    on); ``audited`` is False only for the trivially valid empty prefix
    (no surviving checkpoint)."""

    levels: list[list[Code]]
    supports: dict[Code, int]
    minsup: Optional[int]
    last_level: int                     # deepest audited complete level
    reason: str                         # "deadline" | "budget-exhausted"
    audited: bool
    complete: bool = False
    events: list[dict] = dataclasses.field(default_factory=list)

    @property
    def frequent(self) -> dict[Code, int]:
        return self.supports

    def counts(self) -> list[int]:
        return [len(l) for l in self.levels]


def decode_saved_levels(state: dict) -> tuple[list[list[Code]],
                                              dict[Code, int]]:
    """Decode a checkpoint's (levels, supports) arrays back into codes —
    shared by resume and the supervisor's partial-result cut."""
    levels = [[array_to_code(a) for a in lvl] for lvl in state["levels"]]
    supports = {array_to_code(a): int(s) for a, s in
                zip(state["support_codes"], state["support_vals"])}
    return levels, supports


@dataclasses.dataclass
class _LevelOutcome:
    """What one mined level hands back to the driver loop, identical for
    both pipelines."""

    gsup: np.ndarray            # (C,) global supports, canonical order
    keep: np.ndarray            # survivor candidate indices
    pol: jnp.ndarray            # next-level OL store (compact survivors)
    pmask: jnp.ndarray
    src: jnp.ndarray            # edge store (repacked iff rebalanced)
    dst: jnp.ndarray
    emask: jnp.ndarray
    overflow: int
    max_embeddings: int         # M after any escalation
    rebalanced: bool
    imbalance: float
    perm: Optional[np.ndarray]  # applied partition permutation (or None)
    map_seconds: float
    escalations: int
    retried: bool = False       # level took a materialize-only retry
    survivor_cap: int = 0       # S the level program was dispatched with
    # candidates for the NEXT level, speculatively generated from ALL of
    # this level's candidates while the device program was in flight;
    # the driver narrows them to the surviving parents (None = not
    # speculated — regenerate from F_{k+1} as usual)
    spec_cands: Optional[list[Candidate]] = None
    candgen_seconds: float = 0.0
    # device audit word from the wire (0 = every invariant held; the
    # legacy pipeline computes no word and always reports 0)
    audit: int = 0


class Mirage:
    """The distributed miner.  ``mesh=None`` uses a single-device mesh
    (tests/CPU); production passes ``MiningMesh(make_production_mesh())``.
    """

    def __init__(self, config: MirageConfig,
                 mesh: Optional[MiningMesh] = None):
        self.cfg = config
        self.mesh = mesh or MiningMesh.single_device()
        # introspection for the last device-loop run (tests + residency
        # gate): {"completed": bool, "fallback": Optional[str], ...};
        # None until a device_loop fit has executed
        self.last_device_loop: Optional[dict] = None
        # per-run invariant auditor (§14); rebuilt by each fit() once
        # minsup and the DB graph count are known
        self.auditor: Optional[Auditor] = None
        self._watchdog: Optional[Watchdog] = None
        self._ckpt_meta: dict = {}
        if config.n_partitions % self.mesh.n_workers:
            raise ValueError(
                f"n_partitions={config.n_partitions} must be a multiple of "
                f"the worker count {self.mesh.n_workers}")

    # ------------------------------------------------------------------
    def _effective_partitions(self, n_graphs: int) -> int:
        """Clamp n_partitions to the database size (a partition with no
        graphs would silently pad) while staying a multiple of the
        worker count."""
        cfg, W = self.cfg, self.mesh.n_workers
        if n_graphs == 0 or cfg.n_partitions <= n_graphs:
            return cfg.n_partitions
        clamped = max(W, n_graphs - n_graphs % W)
        if clamped > n_graphs:
            raise ValueError(
                f"database has {n_graphs} graphs but the mesh has {W} "
                f"workers — need at least one graph per worker")
        return clamped

    # ------------------------------------------------------------------
    def fit(self, graphs: Sequence[Graph], *, resume: bool = False,
            watchdog: Optional[Watchdog] = None,
            deadline_s: Optional[float] = None) -> DistMiningResult:
        cfg = self.cfg

        # peek the checkpoint first: the partition count is baked into
        # the saved OL store, and the clamp below depends on the mesh —
        # a resume must reproduce the WRITER's partitioning, not
        # re-derive one from the (possibly different) current mesh
        resume_state = resume_meta = None
        if resume and cfg.checkpoint_dir and ckpt.latest_step(cfg.checkpoint_dir):
            try:
                resume_state, resume_meta = ckpt.load_step(cfg.checkpoint_dir)
            except FileNotFoundError:
                # every on-disk step failed integrity verification and
                # was reaped — a fresh start is the only sound option
                resume_state = resume_meta = None

        # ---- phase 1: partition (host) --------------------------------
        if resume_state is not None:
            n_parts = int(resume_state["pol"].shape[0])
            if n_parts % self.mesh.n_workers:
                raise ValueError(
                    f"checkpoint holds {n_parts} partitions, not a "
                    f"multiple of the current worker count "
                    f"{self.mesh.n_workers} — resume on a compatible mesh")
        else:
            n_parts = self._effective_partitions(len(graphs))
        part = make_partitions(graphs, cfg.minsup, n_parts,
                               scheme=cfg.scheme)
        alphabet, minsup = part.alphabet, part.minsup
        triples = sorted({t for c in alphabet.canonical()
                          for t in (c, (c[2], c[1], c[0]))})
        if not triples:
            return DistMiningResult([], {}, [], alphabet, minsup, 0)

        # ---- §14 run plumbing: auditor + deadline watchdog -------------
        n_graphs = part.n_graphs
        self.auditor = (Auditor(minsup=minsup, n_graphs=n_graphs,
                                samples=cfg.audit_samples)
                        if cfg.audit else None)
        wd = watchdog
        if wd is None and deadline_s is not None:
            wd = Watchdog(deadline_s,
                          phase_floor=cfg.level_deadline_floor,
                          phase_slack=cfg.level_deadline_slack)
        self._watchdog = wd
        if wd is not None:
            wd.start()
        # checkpoint metadata the supervisor's partial-result cut reads:
        # a step is a candidate cut point only when it was written by an
        # auditing run (and its prefix re-verifies on load)
        self._ckpt_meta = {"audited": bool(cfg.audit),
                           "minsup": int(minsup),
                           "n_graphs": int(n_graphs)}

        # ---- phase 2: preparation (host, once) -------------------------
        G = max((len(p) for p in part.partitions), default=1)
        eols = [build_edge_ol(p, triples, pad_graphs=G, max_occ=cfg.max_occ)
                for p in part.partitions]
        F = max(e.src.shape[-1] for e in eols)
        src = np.stack([_pad_f(e.src, F, -1) for e in eols])       # (NP,T,G,F)
        dst = np.stack([_pad_f(e.dst, F, -1) for e in eols])
        emask = np.stack([_pad_f(e.mask, F, False) for e in eols])
        eol0 = eols[0]   # triple_index identical across partitions

        codes = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
        # level-1 embeddings/graph are bounded by F (the edge-OL width), so
        # M1 = F is exact by construction — no silent truncation at level 1.
        bk = self._buckets()
        M1 = max(cfg.max_embeddings, F)
        if bk is not None:
            M1 = bk.embeddings(M1, cfg.max_embeddings)
        lvl1 = [level1_ol(codes, e, max_embeddings=M1) for e in eols]
        pol = np.stack([np.asarray(l.ol) for l in lvl1])           # (NP,P,G,M,2)
        pmask = np.stack([np.asarray(l.mask) for l in lvl1])
        if bk is not None:
            # bucket the level-1 store into the same (P, K) family the
            # child stores live in, so the level-2 program is often THE
            # program every later level reuses
            pol, pmask = _pad_store(
                pol, pmask, p_to=bucket_size(len(codes), bk.s_floor),
                k_to=bk.vertex_slots(2))

        supports: dict[Code, int] = {}
        for c in codes:
            ti = eol0.triple_index[c[0][2:]]
            supports[c] = int(emask[:, ti].any(axis=-1).sum())
        levels: list[list[Code]] = [list(codes)]
        stats: list[LevelStats] = []
        total_overflow = 0
        start_level = 1
        M = cfg.max_embeddings

        # ---- resume (elastic: mesh may differ from writer's) ----------
        if resume_state is not None:
            state = resume_state
            levels, supports = decode_saved_levels(state)
            pol, pmask = state["pol"], state["pmask"]
            start_level = int(resume_meta["step"])
            M = int(state["max_embeddings"])
            total_overflow = int(state["total_overflow"])
            # checkpoints store the CANONICAL (unpadded) survivor store;
            # re-bucket it into the CURRENT config's family — the writer
            # may have used different floors (or none)
            pol, pmask = self._repad_saved(pol, pmask)

        pol, pmask, src_d, dst_d, emask_d = self._device_put(
            pol, pmask, src, dst, emask)

        # cumulative partition permutation from straggler rebalancing;
        # checkpoints always store the OL store in CANONICAL order so a
        # resumed run (which rebuilds edge-OLs canonically) stays aligned
        order = np.arange(n_parts)
        # per-level (n_parents, n_candidates, n_keep) history drives the
        # next level's compaction cap from the measured per-parent fanout
        # (single-sync pipeline); empty = no history yet
        history: list[tuple[int, int, int]] = []
        # bit-packed support path: the 2x-uint16 wire slice needs every
        # global support to fit uint16 — supports are bounded by |G|
        packed = self._packed_support(part.n_graphs)
        # fused tile_c, pinned ONCE per run from the level-2 candidate
        # grouping: per-level adaptive widths would reshape the tile
        # schedule (and recompile the level program) every level
        tile_pin: Optional[int] = None
        # donation re-arming: a resumed run already has a rebuildable
        # checkpoint; a fresh run earns one at its first _save
        policy = DonationPolicy(
            cfg.donation_rearm_levels,
            can_rebuild=bool(cfg.checkpoint_dir) and resume_state is not None)

        # ---- device-resident whole-run loop (DESIGN.md §13) ------------
        if cfg.pipeline == "device_loop" and start_level < cfg.max_size:
            try:
                return self._mine_device_loop(
                    alphabet, minsup, triples, eol0, levels, supports,
                    pol, pmask, src_d, dst_d, emask_d, packed=packed,
                    start_k=start_level, total_overflow=total_overflow,
                    order=order)
            except dloop.DeviceLoopFallback as bail:
                # a static budget tripped (or the M valve hit its
                # ceiling): replay the run through the per-level
                # pipeline below — it has no static budgets and mines
                # the identical frequent set (§10 ladder, rung 2)
                self.last_device_loop = {"completed": False,
                                         "fallback": str(bail),
                                         "chunks": 0, "escalations": 0}

        # ---- phase 3: iterative mining ---------------------------------
        k = start_level
        # overlapped candgen (DESIGN.md §11): each single-sync level
        # speculatively generates the NEXT level's candidates while its
        # device program is in flight; the narrowed result carries over
        # here so the loop head only regenerates when no speculation ran
        cands: Optional[list[Candidate]] = None
        # speculation cost gate inputs (see overlap_spec_window): EWMA
        # per-parent candgen rate, sampled from EVERY generation (fresh
        # and speculative), and the last level's device-only seconds
        cand_rate: Optional[float] = None
        prev_dev = 0.0
        while cfg.max_size is None or k < cfg.max_size:
            t0 = time.perf_counter()
            if wd is not None:
                # cooperative run-deadline check at the loop head — the
                # only place a DeadlineExceeded can safely unwind from
                wd.check_run(level=k + 1)
            if cands is None and cfg.candgen == "device":
                # the stepping-stone device candgen: one jitted
                # device_candidates dispatch instead of the host
                # generator (None = per-level budget overflow → fall
                # back to the host generator for this level only)
                cands = self._device_candgen(levels[-1], triples)
            if cands is None:
                cands = generate_candidates(levels[-1], alphabet)
                if levels[-1]:
                    r = (time.perf_counter() - t0) / len(levels[-1])
                    cand_rate = (r if cand_rate is None
                                 else 0.5 * (cand_rate + r))
            if not cands:
                break
            # chaos hook: a scheduled worker death at this level
            faults.maybe_raise("level_start", k + 1)
            n_parents = len(levels[-1])
            meta = candidate_meta(cands, eol0)
            C = meta.shape[0]
            Cp = (bk.candidates(C, self.mesh.n_workers) if bk is not None
                  else round_up_multiple(C, self.mesh.n_workers))
            meta_p = np.concatenate(
                [meta, np.tile([[0, 0, 0, 1, 0]], (Cp - C, 1))]).astype(np.int32)

            # parent supports for the device audit word (§14): one
            # int32 per parent pattern, indexed on device through the
            # meta parent column (-1 = unknown, e.g. a resumed run
            # whose map predates the parent) — monotonicity
            # gsup <= psup[parent] is anti-monotone pruning's invariant
            psup = None
            if cfg.audit and cfg.pipeline != "legacy":
                psup = np.array(
                    [supports.get(p, -1) for p in levels[-1]], np.int32)
            if wd is not None:
                # arm the phase deadline around the device dispatch —
                # the stretch a hang would otherwise block unobserved
                wd.arm(level=k + 1)

            if cfg.pipeline == "legacy":
                out = self._level_legacy(
                    meta_p, meta, C, pol, pmask, src_d, dst_d, emask_d,
                    minsup, M, n_parts, level=k + 1)
            else:
                # child patterns (size k+1) have at most k+2 vertices;
                # the bucketed width reuses the parent store's while the
                # child still fits, so the arena shape repeats
                child_width = (bk.vertex_slots(k + 2, int(pol.shape[-1]))
                               if bk is not None else None)
                if (tile_pin is None and bk is not None
                        and is_fused_backend(cfg.backend)):
                    # level 2 is the widest, most parent-diverse grouping
                    # the run will see — its adaptive choice generalizes;
                    # later levels reuse it so the schedule shapes (and
                    # the compiled level program) stay fixed
                    tile_pin = schedule_candidates(meta).tile_c
                try:
                    out = self._level_single_sync(
                        meta_p, meta, C, pol, pmask, src_d, dst_d, emask_d,
                        minsup, M, history, child_width,
                        level=k + 1, policy=policy,
                        packed=packed, tile_c=tile_pin,
                        cands=cands, alphabet=alphabet,
                        cand_rate=cand_rate,
                        spec_window=max(prev_dev,
                                        cfg.overlap_spec_window),
                        psup=psup, n_graphs=n_graphs)
                except DonationRetryRebuild:
                    # the armed-donation gamble lost: the arena consumed
                    # the parents, so restore them from the latest intact
                    # checkpoint (canonical store re-padded + cumulative
                    # rebalance permutation re-applied) and replay
                    if wd is not None:
                        wd.disarm()
                    pol, pmask = self._rebuild_parents(order)
                    policy.record_rebuild()
                    continue
                policy.record(out.retried)
            if wd is not None:
                # feed the level's wall-time into the EWMA the next
                # phase deadline is derived from
                wd.disarm(observe_s=time.perf_counter() - t0)
            if self.auditor is not None:
                self.auditor.check_wire(k + 1, out.audit)
                if len(out.keep):
                    self.auditor.check_level(
                        k + 1, cands=cands, keep=out.keep, gsup=out.gsup,
                        parents=levels[-1], supports=supports)
            prev_dev = max(out.map_seconds - out.candgen_seconds, 0.0)
            if out.spec_cands is not None and cands:
                r = out.candgen_seconds / len(cands)
                cand_rate = (r if cand_rate is None
                             else 0.5 * (cand_rate + r))
            M = out.max_embeddings
            total_overflow += out.overflow

            if len(out.keep) == 0:
                stats.append(LevelStats(k + 1, C, 0, out.overflow,
                                        time.perf_counter() - t0,
                                        out.map_seconds, False, out.imbalance,
                                        out.escalations, out.candgen_seconds,
                                        survivor_cap=out.survivor_cap,
                                        retried=out.retried))
                break

            pol, pmask = out.pol, out.pmask
            src_d, dst_d, emask_d = out.src, out.dst, out.emask
            levels.append([cands[i].code for i in out.keep])
            for i in out.keep:
                supports[cands[i].code] = int(out.gsup[i])
            if out.perm is not None:
                order = order[out.perm]
            history.append((n_parents, C, len(out.keep)))

            stats.append(LevelStats(k + 1, C, len(out.keep), out.overflow,
                                    time.perf_counter() - t0,
                                    out.map_seconds, out.rebalanced,
                                    out.imbalance, out.escalations,
                                    out.candgen_seconds,
                                    survivor_cap=out.survivor_cap,
                                    retried=out.retried))

            if cfg.checkpoint_dir:
                self._save(cfg.checkpoint_dir, k + 1, levels, supports,
                           pol, pmask, M, total_overflow, order)
                policy.can_rebuild = True
            # narrow this level's speculative superset (generated from
            # ALL candidates) to the surviving parents — provably equal
            # to generate_candidates(F_{k+1}), see filter_speculative
            cands = (filter_speculative(out.spec_cands, out.keep)
                     if out.spec_cands is not None else None)
            k += 1

        return DistMiningResult(levels, supports, stats, alphabet, minsup,
                                total_overflow)

    # the paper's verb; the supervisor wraps this entrypoint
    mine = fit

    # ------------------------------------------------------------------
    def _repad_saved(self, pol, pmask):
        """Re-bucket a checkpoint's canonical (padding-stripped) survivor
        store into the CURRENT config's shape family — shared by resume
        and mid-run parent rebuild.  No-op without bucketing."""
        bk = self._buckets()
        if bk is None:
            return pol, pmask
        return _pad_store(
            pol, pmask,
            p_to=bucket_size(pol.shape[1], bk.s_floor),
            m_to=bk.embeddings(pol.shape[3], self.cfg.max_embeddings),
            k_to=bk.vertex_slots(pol.shape[-1]))

    def _rebuild_parents(self, order: np.ndarray):
        """Restore the parent OL store of the level being replayed from
        the latest intact checkpoint: canonical store → current bucket
        family → the live partition order (checkpoints are canonical;
        ``order`` is the cumulative rebalance permutation, unchanged
        since that save because rebalances apply only to levels that
        completed)."""
        state, _ = ckpt.load_step(self.cfg.checkpoint_dir)
        pol, pmask = self._repad_saved(state["pol"], state["pmask"])
        pol, pmask = pol[order], pmask[order]
        sharding = partition_sharding(self.mesh.mesh)
        return (jax.device_put(jnp.asarray(pol), sharding),
                jax.device_put(jnp.asarray(pmask), sharding))

    # ------------------------------------------------------------------
    def _sharded_wire(self) -> bool:
        """Resolve the sharded-wire tri-state: explicit config wins;
        auto means on whenever the reduce_scatter shuffle runs under the
        single-sync pipeline (the support slice already lives sharded on
        each worker — gathering it just to re-slice host-side is the
        waste the layout removes).  The device-loop pipeline never
        shards: its wire is the ONE replicated run wire (a fallback run
        through ``_level_single_sync`` then uses the dense layout)."""
        cfg = self.cfg
        if cfg.pipeline != "single_sync":
            return False
        if cfg.sharded_wire is not None:
            return cfg.sharded_wire
        return cfg.reduce == "reduce_scatter"

    # ------------------------------------------------------------------
    def _packed_support(self, n_graphs: int) -> bool:
        """Resolve the packed-support tri-state: explicit config wins
        (True was validated against the legacy pipeline at construction);
        auto means default-ON for the single-sync pipeline.  Either way
        packing additionally requires every global support to fit uint16
        (the wire ships 2 supports per uint32 word) — supports are
        bounded by the database's graph count, checked here."""
        cfg = self.cfg
        if cfg.pipeline not in ("single_sync", "device_loop"):
            return False
        on = (cfg.packed_support if cfg.packed_support is not None
              else True)
        return bool(on) and n_graphs < (1 << 16)

    # ------------------------------------------------------------------
    def _buckets(self) -> Optional[BucketSpec]:
        """The run's shape-bucket family, or None when bucketing is off.
        The legacy pipeline never buckets — it is the PR-1 differential
        oracle and must stay bit-identical to it."""
        cfg = self.cfg
        if (not cfg.bucket_shapes
                or cfg.pipeline not in ("single_sync", "device_loop")):
            return None
        return BucketSpec(cfg.bucket_c_floor, cfg.bucket_s_floor,
                          cfg.bucket_k_floor)

    # ------------------------------------------------------------------
    def _survivor_cap(self, C: int, Cp: int,
                      history: list[tuple[int, int, int]]) -> int:
        """Static survivor cap for the level program's compaction stage.

        Cap padding slots are cond-gated on device (they execute a
        constant fill, not a materialization), so the cap only governs
        the child store's HBM footprint; a miss costs one
        materialize-only retry dispatch (the pass-1 supports stay
        valid).  Policy: predict the next survivor count from the
        previous level's measured per-parent fanout —
        ``keep_prev / parents_prev`` survivors per parent times the
        ``keep_prev`` parents this level mines from, scaled by the
        configured slack — or a quarter of the candidate space when
        there is no history yet.  (The earlier survival-RATIO predictor
        multiplied by the CURRENT candidate count C, which balloons with
        the parent set and over-padded the arena by the fanout squared
        on expanding runs.)

        Under shape bucketing the prediction is rounded to the S-bucket
        family and clamped at the (bucketed) Cp ceiling: a cap miss
        then retries into the NEXT family member, and near-boundary
        predictions cannot thrash between adjacent raw values — both
        would recompile the level program every flip."""
        bk = self._buckets()
        if not self.cfg.predict_survivors:
            # no prediction = no cap miss allowed: S must cover every
            # real candidate.  Bucketed, the smallest S-family member
            # >= C keeps the arena in the same shape family as the
            # parent axis instead of jumping to the C family.
            return Cp if bk is None else bk.survivors(C, Cp)
        if not history:
            s = min(Cp, max(32, -(-Cp // 4)))
        else:
            parents_prev, _cands_prev, keep_prev = history[-1]
            fanout = keep_prev / max(parents_prev, 1)
            pred = self.cfg.survivor_slack * fanout * max(keep_prev, 1)
            # n_keep <= C always, so C is a sound extra clamp
            s = min(Cp, C, max(1, int(np.ceil(pred)) + 16))
        if bk is not None:
            s = bk.survivors(s, Cp)
        return s

    # ------------------------------------------------------------------
    def _device_candgen(self, parents: list[Code],
                        triples: list[tuple[int, int, int]]
                        ) -> Optional[list[Candidate]]:
        """Per-level device candidate generation (candgen="device"):
        one jitted ``device_candidates`` dispatch replaces the host
        generator, returning the SAME candidates in the SAME order
        (pinned by tests/test_device_loop.py).  Budgets default to the
        exact structural bound — overflow is then impossible unless the
        config pins them tighter; any tripped flag returns None and the
        caller regenerates on host for this level only."""
        cfg = self.cfg
        SP = len(parents)
        if SP == 0:
            return []
        Lk = len(parents[0]) + 1            # child edge count
        NV = Lk + 1                         # child vertex bound
        T = len(triples)
        raw_b = cfg.device_raw_budget or SP * (2 * NV - 1) * T
        budget = cfg.device_c_budget or raw_b
        fn = device_candgen_jit(Lk, NV, raw_b, budget,
                                cfg.device_max_states)
        codes = np.full((SP, Lk, 5), -1, np.int32)
        for i, c in enumerate(parents):
            codes[i] = code_to_array(c, Lk)
        meta, child, n_cand, flags = fn(
            jnp.asarray(codes), jnp.int32(SP),
            jnp.asarray(np.asarray(triples, np.int32)))
        if bool(np.asarray(flags).any()):
            return None
        return candidates_from_arrays(np.asarray(meta), np.asarray(child),
                                      int(n_cand), triples)

    # ------------------------------------------------------------------
    def _decode_device_run(self, rw: "dloop.RunWire", levels0, supports0,
                           start_k: int):
        """Decode a run wire into (levels, supports, stat rows) with the
        host loop's exact stopping semantics: an empty candidate set
        stops BEFORE its stats row (the host breaks at the loop head),
        an empty frequent set stops AFTER it."""
        levels = [list(l) for l in levels0]
        sups = dict(supports0)
        rows: list[tuple[int, int, int, int, float]] = []
        for s in range(start_k - 1, rw.k_final - 1):
            n_cand, n_keep, ovf, imb_fx = (int(x) for x in rw.stats[s, :4])
            if n_cand == 0:
                break
            rows.append((s + 2, n_cand, n_keep, ovf, imb_fx / _IMBAL_FX))
            if n_keep == 0:
                break
            lvl = [array_to_code(rw.codes[s, i]) for i in range(n_keep)]
            levels.append(lvl)
            for i, c in enumerate(lvl):
                sups[c] = int(rw.sups[s, i])
        return levels, sups, rows

    # ------------------------------------------------------------------
    def _mine_device_loop(self, alphabet, minsup, triples, eol0, levels0,
                          supports0, pol, pmask, src, dst, emask, *,
                          packed: bool, start_k: int, total_overflow: int,
                          order: np.ndarray) -> DistMiningResult:
        """The whole run as ONE jitted ``lax.while_loop`` program
        (core/device_loop.py, DESIGN.md §13).

        Candidate generation, schedule, support counting, survivor
        compaction and child materialization all stay on device for
        every level; the host sees exactly ONE run-wire transfer (plus
        wire+store fetches at the optional checkpoint-chunk boundaries).
        Static budgets are sized once from a single host candidate
        generation at the start level — the ONLY host candgen of a
        completed run (pinned by the satellite regression test); a
        budget overflow mid-run trips a bail flag and this method raises
        :class:`~.device_loop.DeviceLoopFallback` so the caller replays
        through the per-level pipeline.

        The exactness valve hoists to run granularity: the loop mines at
        one uniform embedding cap M (the carry shape); an overflowing
        run doubles M and reruns the whole program from the base store —
        pre-overflow levels are bit-identical at the larger M, so the
        rerun converges to the exact escalated host semantics."""
        cfg = self.cfg
        bk = self._buckets()
        W = self.mesh.n_workers
        backend = cfg.backend or default_backend()
        t0 = time.perf_counter()
        L = cfg.max_size
        NL = L - 1
        NV = bk.vertex_slots(L + 1)

        # ---- static budgets from one host generation ------------------
        base = generate_candidates(levels0[-1], alphabet)
        if not base:
            return DistMiningResult(levels0, supports0, [], alphabet,
                                    minsup, total_overflow)
        meta0 = candidate_meta(base, eol0)
        C0 = meta0.shape[0]
        CB = round_up_multiple(cfg.device_c_budget
                               or bk.candidates(4 * C0, W), W)
        CBR = cfg.device_raw_budget or 4 * CB
        SPP = max(bucket_size(len(levels0[-1]), bk.s_floor), CB)
        tile_c, ROWS = 1, CB
        if is_fused_backend(backend):
            sched0 = schedule_candidates(meta0)
            tile_c = sched0.tile_c
            ROWS = round_up_multiple(
                bucket_size(max(2 * sched0.meta.shape[0], CB), bk.c_floor),
                tile_c)

        prog = dloop._run_program(
            self.mesh, minsup, backend, cfg.reduce, packed, L, NV, CB,
            CBR, cfg.device_max_states, NL, tile_c, ROWS, len(triples),
            cfg.device_loop_unroll)

        # ---- device-resident carry ------------------------------------
        trip_a = jnp.asarray(np.asarray(triples, np.int32))
        codes_h = np.full((SPP, L, 5), -1, np.int32)
        for i, c in enumerate(levels0[-1]):
            codes_h[i] = code_to_array(c, L)
        n_par0 = len(levels0[-1])
        sharding = partition_sharding(self.mesh.mesh)
        pol0, pmask0 = _pad_store(pol, pmask, p_to=SPP, k_to=NV)
        pol0 = jax.device_put(jnp.asarray(pol0), sharding)
        pmask0 = jax.device_put(jnp.asarray(pmask0), sharding)
        M_run = int(pol0.shape[3])
        oc0 = jnp.asarray(np.full((NL, SPP, L, 5), -1, np.int32))
        os0 = jnp.asarray(np.zeros((NL, SPP), np.int32))
        ost0 = jnp.asarray(np.zeros((NL, dloop.NSTAT), np.int32))

        cadence = ckpt.ChunkCadence(start_k, L,
                                    cfg.device_loop_ckpt_every)
        escalations = chunks = 0
        pol_b, pmask_b = pol0, pmask0
        rw = carry = None
        wd = self._watchdog
        while True:                 # run-granular escalation valve
            carry = (jnp.int32(start_k), jnp.int32(n_par0),
                     jnp.asarray(codes_h), trip_a, pol_b, pmask_b,
                     src, dst, emask, oc0, os0, ost0,
                     jnp.asarray(True), jnp.int32(0))
            k_cur, escalate = start_k, False
            for k_stop in cadence.boundaries():
                if wd is not None:
                    # each ChunkCadence re-invocation doubles as a
                    # heartbeat: the run-deadline check fires here, and
                    # the phase deadline re-arms over the coming chunk
                    wd.check_run(level=k_stop)
                    wd.arm(level=k_stop)
                t_chunk = time.perf_counter()
                for lv in range(k_cur + 1, k_stop + 1):
                    # chaos hooks, fired host-side per window level so
                    # fault schedules hit device-loop runs too
                    faults.maybe_raise("level_start", lv)
                    faults.maybe_raise("kernel", lv)
                calls = (1 if cfg.device_loop_unroll <= 0 else
                         -(-(k_stop - k_cur) // cfg.device_loop_unroll))
                for _ in range(calls):
                    out = prog(jnp.int32(k_stop), *carry)
                    carry = (out[1], out[2], out[3], trip_a, out[4],
                             out[5], src, dst, emask, out[6], out[7],
                             out[8], out[9], out[10])
                chunks += 1
                # chaos hook: a stalled chunk — the armed phase deadline
                # (and the device_loop→single_sync rung) bounds it
                faults.maybe_hang("chunk", k_stop, wd)
                # the chunk boundary's (only) host contact
                body = fetch_wire(out[0], level=k_stop)
                rw = dloop.decode_run_wire(body, NL, SPP, L)
                k_cur = k_stop
                if wd is not None:
                    wd.disarm(observe_s=time.perf_counter() - t_chunk)
                if not rw.ok or rw.n_par == 0:
                    break
                if (rw.total_overflow > 0
                        and M_run < cfg.max_embeddings_limit):
                    escalate = True
                    break
                if cfg.checkpoint_dir and k_cur < L:
                    levels, sups, _ = self._decode_device_run(
                        rw, levels0, supports0, start_k)
                    if self.auditor is not None:
                        # a boundary save is a potential partial-result
                        # cut point: audit the whole decoded prefix
                        # BEFORE it reaches disk as "audited"
                        self.auditor.check_levels(levels, sups)
                    self._save(cfg.checkpoint_dir, k_cur, levels, sups,
                               np.asarray(carry[4]), np.asarray(carry[5]),
                               M_run,
                               total_overflow + rw.total_overflow, order)
            if not escalate:
                break
            M_run = min(M_run * 2, cfg.max_embeddings_limit)
            escalations += 1
            pol_b, pmask_b = _pad_store(pol0, pmask0, m_to=M_run)
            pol_b = jax.device_put(jnp.asarray(pol_b), sharding)
            pmask_b = jax.device_put(jnp.asarray(pmask_b), sharding)

        if not rw.ok:
            bad = int(np.bitwise_or.reduce(
                rw.stats[:, 4].astype(np.int64)))
            raise dloop.DeviceLoopFallback(
                f"device loop bailed at level {rw.k_final} "
                f"(flags=0b{bad:04b}: CB={CB} CBR={CBR} "
                f"states={cfg.device_max_states} rows={ROWS})")
        if rw.total_overflow > 0:
            raise dloop.DeviceLoopFallback(
                f"M-cap overflow {rw.total_overflow} persists at the "
                f"max_embeddings_limit={cfg.max_embeddings_limit} ceiling")

        levels, sups, rows = self._decode_device_run(
            rw, levels0, supports0, start_k)
        if self.auditor is not None:
            self.auditor.check_levels(levels, sups)
        tovf = total_overflow + rw.total_overflow
        elapsed = time.perf_counter() - t0
        per = elapsed / max(len(rows), 1)
        stats = [LevelStats(lv, nc, nk, ov, per, per, False, imb,
                            escalations if i == 0 else 0,
                            survivor_cap=SPP)
                 for i, (lv, nc, nk, ov, imb) in enumerate(rows)]
        if cfg.checkpoint_dir and rw.n_par > 0:
            # the carry store row-aligns with levels[-1] only when the
            # run ended WITH survivors; a zero-survivor tail keeps the
            # last boundary save instead
            self._save(cfg.checkpoint_dir, len(levels), levels, sups,
                       np.asarray(carry[4]), np.asarray(carry[5]),
                       M_run, tovf, order)
        self.last_device_loop = {
            "completed": True, "fallback": None, "chunks": chunks,
            "escalations": escalations, "c_budget": CB,
            "raw_budget": CBR, "sched_rows": ROWS, "spp": SPP,
            "max_embeddings": M_run, "n_levels": NL, "tile_c": tile_c,
        }
        return DistMiningResult(levels, sups, stats, alphabet, minsup,
                                tovf)

    def _level_single_sync(self, meta_p, meta, C, pol, pmask, src, dst,
                           emask, minsup, M, history,
                           child_width: Optional[int] = None, *,
                           level: Optional[int] = None,
                           policy: Optional[DonationPolicy] = None,
                           cands: Optional[list[Candidate]] = None,
                           alphabet: Optional[EdgeAlphabet] = None,
                           cand_rate: Optional[float] = None,
                           spec_window: Optional[float] = None,
                           packed: bool = False,
                           tile_c: Optional[int] = None,
                           psup: Optional[np.ndarray] = None,
                           n_graphs: int = -1
                           ) -> _LevelOutcome:
        """One level through the device-resident program: a single
        dispatch and a single device→host sync on the wire vector.

        The dispatch is asynchronous (:class:`~.level_step.PendingLevel`):
        with ``overlap_candgen`` the host generates the NEXT level's
        candidates from this level's FULL candidate list (a superset of
        the frequent set — per-parent generation is independent, so the
        driver later narrows it exactly) while the device program runs,
        and blocks on the wire only afterwards.  The speculation only
        runs when its estimated cost (``cand_rate`` seconds/parent ×
        the superset size) fits the ``spec_window`` it would hide in —
        at sparse survival the superset is many times the frequent set
        and generating it would cost far more than it saves.

        Exceptional paths re-use the still-valid pass-1 supports and fall
        back to the cheap materialize-only program from the preserved
        inputs: a survivor-cap miss re-materializes the full survivor
        set, and the escalation valve re-materializes at a doubled M.
        Donation is engaged when no such retry is possible — or when the
        re-arming policy is armed (enough clean levels + a rebuildable
        checkpoint); an armed level that then DOES need its retry raises
        :class:`DonationRetryRebuild` instead, because donation already
        consumed the parents."""
        cfg = self.cfg
        bk = self._buckets()
        Cp = meta_p.shape[0]
        backend = cfg.backend or default_backend()
        S = self._survivor_cap(C, Cp, history)
        # chaos hook: a cap-miss storm forces a pathological cap, driving
        # every hit level through the materialize-only retry path
        S = faults.override_cap(S, level)
        # a cap miss needs n_keep > S, and n_keep <= C always — S >= C
        # rules the retry out even when S sits below the padded Cp
        may_retry = (S < C or (cfg.escalate_on_overflow
                               and M < cfg.max_embeddings_limit))
        donated = cfg.donate and (not may_retry
                                  or (policy is not None and policy.armed))
        t_map = time.perf_counter()
        pending = dispatch_level(
            self.mesh, meta_p, C, pol, pmask, src, dst, emask,
            minsup=minsup, backend=backend, reduce=cfg.reduce,
            max_embeddings=M, survivor_cap=S,
            rebalance=cfg.rebalance, threshold=cfg.rebalance_threshold,
            donate=donated,
            child_width=child_width,
            sched_floor=bk.c_floor if bk is not None else None,
            level=level, sharded=self._sharded_wire(),
            packed=packed, tile_c=tile_c,
            psup=psup, n_graphs=n_graphs)
        # chaos hook: an injected stall while the program is in flight —
        # the watchdog's armed phase deadline is what bounds it
        faults.maybe_hang("dispatch", level, self._watchdog)
        # the overlap window: the device program is in flight, the host
        # is free — speculate the next level's candidates now
        spec_cands = None
        cand_secs = 0.0
        if cfg.overlap_candgen and cands is not None and alphabet is not None:
            window = (cfg.overlap_spec_window if spec_window is None
                      else spec_window)
            est = (cand_rate or 0.0) * len(cands)
            if est <= window:
                t_cand = time.perf_counter()
                spec_cands = generate_candidates([c.code for c in cands],
                                                 alphabet)
                cand_secs = time.perf_counter() - t_cand
        out = pending.finish()
        w = out.wire
        map_secs = time.perf_counter() - t_map

        keep = np.flatnonzero(w.gsup >= minsup)
        n = int(w.n_keep)
        overflow = w.overflow
        escalations = 0
        if bk is None:
            new_pol = out.pol[:, :max(n, 1)]
            new_pmask = out.pmask[:, :max(n, 1)]
        else:
            # keep the full S-bucket arena: slicing to the survivor
            # count would hand the next level a fresh shape (and a
            # fresh compile) every time n moves
            new_pol, new_pmask = out.pol, out.pmask

        escalatable = (cfg.escalate_on_overflow
                       and M < cfg.max_embeddings_limit)
        retried = bool(n > 0 and (n > S or (overflow > 0 and escalatable)))
        if retried:
            if donated:
                # armed-donation gamble lost: the parents are gone (the
                # arena aliased them) — the driver rebuilds from
                # checkpoint and replays this level
                raise DonationRetryRebuild(level if level is not None else -1)
            if overflow > 0 and escalatable:
                # the program just proved M too small (for a cap miss,
                # on a subset of survivors — still a proof): skip the
                # known-bad M before re-materializing
                M = min(M * 2, cfg.max_embeddings_limit)
                escalations += 1
            new_pol, new_pmask, overflow, M, esc = self._materialize_exact(
                jnp.asarray(meta[keep]), pol, pmask, src, dst, emask, M,
                out_width=child_width)
            escalations += esc
            if bk is not None:
                # re-bucket the retried store so the next level stays in
                # the family (the cap miss means n outgrew S's bucket)
                new_pol, new_pmask = _pad_store(
                    new_pol, new_pmask, p_to=bk.survivors(len(keep), Cp))

        if w.rebalanced and n > 0:
            # apply the wire-reported LPT permutation on device (no sync)
            new_pol, new_pmask, src, dst, emask = permute_stores(
                self.mesh, w.perm, new_pol, new_pmask, src, dst, emask)

        return _LevelOutcome(
            gsup=w.gsup, keep=keep, pol=new_pol, pmask=new_pmask,
            src=src, dst=dst, emask=emask,
            overflow=overflow, max_embeddings=M,
            rebalanced=w.rebalanced and n > 0, imbalance=w.imbalance,
            perm=w.perm if (w.rebalanced and n > 0) else None,
            map_seconds=map_secs, escalations=escalations,
            retried=retried, survivor_cap=S, spec_cands=spec_cands,
            candgen_seconds=cand_secs, audit=int(w.audit))

    # ------------------------------------------------------------------
    def _level_legacy(self, meta_p, meta, C, pol, pmask, src, dst, emask,
                      minsup, M, n_parts, *,
                      level: Optional[int] = None) -> _LevelOutcome:
        """The PR-1 driver: separate support and materialize programs
        with host round-trips between them (keep list, escalation loop,
        LPT detour).  Kept as differential oracle + benchmark baseline."""
        cfg = self.cfg
        t_map = time.perf_counter()
        gsup, verdict, emb_pp = map_reduce_supports(
            self.mesh, meta_p, pol, pmask, src, dst, emask,
            minsup=minsup, backend=cfg.backend, reduce=cfg.reduce)
        faults.maybe_hang("dispatch", level, self._watchdog)
        map_secs = time.perf_counter() - t_map

        keep = np.flatnonzero(verdict[:C] != 0)
        if len(keep) == 0:
            return _LevelOutcome(
                gsup=gsup[:C], keep=keep, pol=pol, pmask=pmask,
                src=src, dst=dst, emask=emask, overflow=0,
                max_embeddings=M, rebalanced=False, imbalance=1.0,
                perm=None, map_seconds=map_secs, escalations=0)

        keep_meta = jnp.asarray(meta[keep])
        pol, pmask, overflow, M, escalations = self._materialize_exact(
            keep_meta, pol, pmask, src, dst, emask, M)

        # ---- straggler rebalance (cost signal: embed counts) -----------
        cost = emb_pp.reshape(n_parts, -1).sum(-1).astype(np.float64)
        imbal = _imbalance(cost, self.mesh.n_workers)
        rebalanced = False
        perm = None
        if (cfg.rebalance and self.mesh.n_workers > 1
                and imbal > cfg.rebalance_threshold):
            perm = _lpt_order(cost, self.mesh.n_workers)
            take = lambda a: jnp.take(a, jnp.asarray(perm), axis=0)
            pol, pmask = take(pol), take(pmask)
            src, dst, emask = take(src), take(dst), take(emask)
            rebalanced = True
        return _LevelOutcome(
            gsup=gsup[:C], keep=keep, pol=pol, pmask=pmask,
            src=src, dst=dst, emask=emask, overflow=overflow,
            max_embeddings=M, rebalanced=rebalanced, imbalance=imbal,
            perm=perm, map_seconds=map_secs, escalations=escalations)

    # ------------------------------------------------------------------
    def _materialize_exact(self, keep_meta, pol, pmask, src, dst, emask, M,
                           out_width: Optional[int] = None):
        """Materialize survivors; escalate M until no overflow (exactness
        valve — keeps device supports == paper semantics)."""
        cfg = self.cfg
        escalations = 0
        while True:
            new_pol, new_pmask, overflow = map_materialize(
                self.mesh, keep_meta, pol, pmask, src, dst, emask,
                max_embeddings=M, out_width=out_width)
            if (overflow == 0 or not cfg.escalate_on_overflow
                    or M >= cfg.max_embeddings_limit):
                return new_pol, new_pmask, overflow, M, escalations
            M = min(M * 2, cfg.max_embeddings_limit)
            escalations += 1

    def _device_put(self, pol, pmask, src, dst, emask):
        sharding = partition_sharding(self.mesh.mesh)
        return tuple(jax.device_put(jnp.asarray(x), sharding)
                     for x in (pol, pmask, src, dst, emask))

    def _save(self, root, level, levels, supports, pol, pmask, M, overflow,
              order):
        # invert the cumulative rebalance permutation: checkpoints hold
        # the OL store in canonical partition order (resume rebuilds the
        # edge-OL store canonically and must stay row-aligned)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        max_edges = max(len(c) for l in levels for c in l)
        pol_np, pmask_np = np.asarray(pol)[inv], np.asarray(pmask)[inv]
        # checkpoints hold the CANONICAL store: bucket padding is
        # stripped (pattern axis to the true survivor count, vertex axis
        # to the widest real pattern) so a resume under different bucket
        # floors — or none — re-pads into ITS family without inheriting
        # the writer's.  Unbucketed stores pass through unchanged.
        n_real = max(len(levels[-1]), 1)
        pol_np, pmask_np = pol_np[:, :n_real], pmask_np[:, :n_real]
        if self._buckets() is not None:
            kw = 1 + max(max(i, j) for c in levels[-1]
                         for (i, j, _a, _e, _b) in c)
            pol_np = pol_np[..., :kw]
        state = {
            "levels": [[code_to_array(c, max_edges) for c in l]
                       for l in levels],
            "support_codes": [code_to_array(c, max_edges) for c in supports],
            "support_vals": np.asarray(list(supports.values()), np.int64),
            "pol": pol_np,
            "pmask": pmask_np,
            "max_embeddings": M,
            "total_overflow": overflow,
        }
        # metadata the supervisor's partial-result cut branches on:
        # "audited" marks steps written by an auditing run (the only
        # levels a PartialResult may ever cut at), minsup + n_graphs
        # parameterize the load-time re-audit
        ckpt.save_step(root, level, state,
                       metadata={"kind": "mirage-mining",
                                 **self._ckpt_meta})


def _pad_store(pol, pmask, *, p_to: Optional[int] = None,
               m_to: Optional[int] = None, k_to: Optional[int] = None):
    """Grow an OL store (NP, P, G, M, K)/(NP, P, G, M) into its bucket:
    PAD(-1) vertex entries, all-False masks.  Padded slots are inert —
    no candidate references a padded parent, masked embeddings never
    join, PAD vertex slots never match.  Works on numpy or device
    arrays (np.pad falls back to jnp dispatch via asarray semantics)."""
    xp = np if isinstance(pol, np.ndarray) else jnp

    def pad(a, axis, to):
        cur = a.shape[axis]
        if to is None or to <= cur:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, to - cur)
        fill = -1 if a.dtype == xp.int32 else False
        return xp.pad(a, widths, constant_values=fill)

    pol = pad(pad(pad(pol, 1, p_to), 3, m_to), 4, k_to)
    pmask = pad(pad(pmask, 1, p_to), 3, m_to)
    return pol, pmask


def _pad_f(a: np.ndarray, F: int, fill) -> np.ndarray:
    pad = F - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return np.pad(a, widths, constant_values=fill)


def _imbalance(cost: np.ndarray, w: int) -> float:
    """max/mean of per-worker cost under the current blocked assignment."""
    per_worker = cost.reshape(w, -1).sum(-1)
    mean = per_worker.mean()
    return float(per_worker.max() / mean) if mean > 0 else 1.0


def _lpt_order(cost: np.ndarray, w: int) -> np.ndarray:
    """Re-pack partitions into w balanced blocks (LPT), then emit the
    permutation that lays blocks contiguously (matching the blocked
    dim-0 sharding)."""
    np_total = len(cost)
    per = np_total // w
    buckets: list[list[int]] = [[] for _ in range(w)]
    load = np.zeros(w)
    for i in np.argsort(-cost):
        # lightest bucket with room
        order = np.argsort(load)
        for b in order:
            if len(buckets[b]) < per:
                buckets[b].append(int(i))
                load[b] += cost[i]
                break
    return np.asarray([i for b in buckets for i in b], np.int32)
