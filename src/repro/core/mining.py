"""MIRAGE iterative mining driver (paper §IV-B/C, Figs. 9-10).

Phases:
  1. data partition  — filter infrequent edges, split into NP partitions
                       (NP ≫ workers, paper Fig. 20), pad uniformly;
  2. preparation     — per-partition static structures (edge-OL,
                       edge-extension map is implied by the triple table)
                       + the level-1 pattern OLs;
  3. mining          — host enumerates canonical candidates from F_k
                       (tiny metadata), devices run the fused join
                       (map), one dense collective aggregates support
                       (shuffle+reduce), survivors' OLs materialize
                       data-locally; repeat until no frequent patterns.

Fault tolerance: every level boundary checkpoints the complete mining
state (codes + OL store + cursor) atomically — the HDFS write of the
paper made explicit.  ``Mirage.fit(..., resume=True)`` replays at most
one level after any failure, and may resume onto a *different* mesh
(elastic: state is saved unsharded, resharded on load).

Straggler mitigation: the join kernel's embed-count output is an exact
per-partition cost signal for the *next* level; when predicted imbalance
exceeds a threshold the partition→device assignment is re-packed (LPT)
and the OL store re-laid-out (one all-to-all-equivalent gather).  This is
deterministic load balancing, replacing Hadoop's speculative execution.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import Backend
from ..runtime import checkpoint as ckpt
from .candgen import Candidate, EdgeAlphabet, generate_candidates
from .dfscode import Code, array_to_code, code_to_array
from .embedding import build_edge_ol, candidate_meta, level1_ol
from .graphdb import Graph
from .mapreduce import MiningMesh, map_materialize, map_reduce_supports
from .partition import make_partitions

__all__ = ["MirageConfig", "LevelStats", "DistMiningResult", "Mirage"]


@dataclasses.dataclass
class MirageConfig:
    minsup: float | int                 # fraction of |G| or absolute count
    n_partitions: int = 8
    scheme: int = 2                     # paper partition scheme (1|2)
    max_size: Optional[int] = None      # max pattern edges (None = to fixpoint)
    max_embeddings: int = 32            # M cap (exactness valve escalates)
    max_embeddings_limit: int = 512     # escalation ceiling
    max_occ: Optional[int] = None       # F pad (None = derive from data)
    backend: Optional[Backend] = None   # kernels backend (None = auto)
    reduce: str = "psum"                # "psum" | "reduce_scatter"
    checkpoint_dir: Optional[str] = None
    escalate_on_overflow: bool = True
    rebalance_threshold: float = 1.25   # max/mean partition cost trigger
    rebalance: bool = True


@dataclasses.dataclass
class LevelStats:
    level: int
    n_candidates: int
    n_frequent: int
    overflow: int
    seconds: float
    map_seconds: float
    rebalanced: bool
    imbalance: float                    # max/mean partition embed-count


@dataclasses.dataclass
class DistMiningResult:
    levels: list[list[Code]]
    supports: dict[Code, int]
    stats: list[LevelStats]
    alphabet: EdgeAlphabet
    minsup: int
    total_overflow: int

    @property
    def frequent(self) -> dict[Code, int]:
        return self.supports

    def counts(self) -> list[int]:
        return [len(l) for l in self.levels]


class Mirage:
    """The distributed miner.  ``mesh=None`` uses a single-device mesh
    (tests/CPU); production passes ``MiningMesh(make_production_mesh())``.
    """

    def __init__(self, config: MirageConfig,
                 mesh: Optional[MiningMesh] = None):
        self.cfg = config
        self.mesh = mesh or MiningMesh.single_device()
        if config.n_partitions % self.mesh.n_workers:
            raise ValueError(
                f"n_partitions={config.n_partitions} must be a multiple of "
                f"the worker count {self.mesh.n_workers}")

    # ------------------------------------------------------------------
    def fit(self, graphs: Sequence[Graph], *, resume: bool = False
            ) -> DistMiningResult:
        cfg = self.cfg
        t_all = time.perf_counter()

        # ---- phase 1: partition (host) --------------------------------
        part = make_partitions(graphs, cfg.minsup, cfg.n_partitions,
                               scheme=cfg.scheme)
        alphabet, minsup = part.alphabet, part.minsup
        triples = sorted({t for c in alphabet.canonical()
                          for t in (c, (c[2], c[1], c[0]))})
        if not triples:
            return DistMiningResult([], {}, [], alphabet, minsup, 0)

        # ---- phase 2: preparation (host, once) -------------------------
        G = max((len(p) for p in part.partitions), default=1)
        eols = [build_edge_ol(p, triples, pad_graphs=G, max_occ=cfg.max_occ)
                for p in part.partitions]
        F = max(e.src.shape[-1] for e in eols)
        src = np.stack([_pad_f(e.src, F, -1) for e in eols])       # (NP,T,G,F)
        dst = np.stack([_pad_f(e.dst, F, -1) for e in eols])
        emask = np.stack([_pad_f(e.mask, F, False) for e in eols])
        eol0 = eols[0]   # triple_index identical across partitions

        codes = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
        # level-1 embeddings/graph are bounded by F (the edge-OL width), so
        # M1 = F is exact by construction — no silent truncation at level 1.
        lvl1 = [level1_ol(codes, e, max_embeddings=max(cfg.max_embeddings, F))
                for e in eols]
        pol = np.stack([np.asarray(l.ol) for l in lvl1])           # (NP,P,G,M,2)
        pmask = np.stack([np.asarray(l.mask) for l in lvl1])

        supports: dict[Code, int] = {}
        for pi, c in enumerate(codes):
            ti = eol0.triple_index[c[0][2:]]
            supports[c] = int(emask[:, ti].any(axis=-1).sum())
        levels: list[list[Code]] = [list(codes)]
        stats: list[LevelStats] = []
        total_overflow = 0
        start_level = 1
        M = cfg.max_embeddings

        # ---- resume (elastic: mesh may differ from writer's) ----------
        if resume and cfg.checkpoint_dir and ckpt.latest_step(cfg.checkpoint_dir):
            state, meta_d = ckpt.load_step(cfg.checkpoint_dir)
            levels = [[array_to_code(a) for a in lvl] for lvl in state["levels"]]
            supports = {array_to_code(a): int(s) for a, s in
                        zip(state["support_codes"], state["support_vals"])}
            pol, pmask = state["pol"], state["pmask"]
            start_level = int(meta_d["step"])
            M = int(state["max_embeddings"])
            total_overflow = int(state["total_overflow"])

        pol, pmask, src_d, dst_d, emask_d = self._device_put(
            pol, pmask, src, dst, emask)

        # cumulative partition permutation from straggler rebalancing;
        # checkpoints always store the OL store in CANONICAL order so a
        # resumed run (which rebuilds edge-OLs canonically) stays aligned
        order = np.arange(cfg.n_partitions)

        # ---- phase 3: iterative mining ---------------------------------
        k = start_level
        while cfg.max_size is None or k < cfg.max_size:
            t0 = time.perf_counter()
            cands = generate_candidates(levels[-1], alphabet)
            if not cands:
                break
            meta = candidate_meta(cands, eol0)
            C = meta.shape[0]
            Cp = _round_up(C, self.mesh.n_workers)
            meta_p = np.concatenate(
                [meta, np.tile([[0, 0, 0, 1, 0]], (Cp - C, 1))]).astype(np.int32)

            t_map = time.perf_counter()
            gsup, verdict, emb_pp = map_reduce_supports(
                self.mesh, meta_p, pol, pmask,
                src_d, dst_d, emask_d,
                minsup=minsup, backend=cfg.backend, reduce=cfg.reduce)
            map_secs = time.perf_counter() - t_map

            keep = [i for i in range(C) if verdict[i]]
            if not keep:
                stats.append(LevelStats(k + 1, C, 0, 0,
                                        time.perf_counter() - t0, map_secs,
                                        False, 1.0))
                break

            keep_meta = jnp.asarray(meta[keep])
            pol, pmask, overflow, M = self._materialize_exact(
                keep_meta, pol, pmask, src_d, dst_d, emask_d, M)
            total_overflow += overflow

            levels.append([cands[i].code for i in keep])
            for i in keep:
                supports[cands[i].code] = int(gsup[i])

            # ---- straggler rebalance (cost signal: embed counts) -------
            cost = emb_pp.reshape(cfg.n_partitions, -1).sum(-1).astype(np.float64)
            imbal = _imbalance(cost, self.mesh.n_workers)
            rebalanced = False
            if (cfg.rebalance and self.mesh.n_workers > 1
                    and imbal > cfg.rebalance_threshold):
                perm = _lpt_order(cost, self.mesh.n_workers)
                take = lambda a: jnp.take(a, jnp.asarray(perm), axis=0)
                pol, pmask = take(pol), take(pmask)
                src_d, dst_d, emask_d = take(src_d), take(dst_d), take(emask_d)
                order = order[perm]
                rebalanced = True

            stats.append(LevelStats(k + 1, C, len(keep), overflow,
                                    time.perf_counter() - t0, map_secs,
                                    rebalanced, imbal))

            if cfg.checkpoint_dir:
                self._save(cfg.checkpoint_dir, k + 1, levels, supports,
                           pol, pmask, M, total_overflow, order)
            k += 1

        return DistMiningResult(levels, supports, stats, alphabet, minsup,
                                total_overflow)

    # ------------------------------------------------------------------
    def _materialize_exact(self, keep_meta, pol, pmask, src, dst, emask, M):
        """Materialize survivors; escalate M until no overflow (exactness
        valve — keeps device supports == paper semantics)."""
        cfg = self.cfg
        while True:
            new_pol, new_pmask, overflow = map_materialize(
                self.mesh, keep_meta, pol, pmask, src, dst, emask,
                max_embeddings=M)
            if (overflow == 0 or not cfg.escalate_on_overflow
                    or M >= cfg.max_embeddings_limit):
                return new_pol, new_pmask, overflow, M
            M = min(M * 2, cfg.max_embeddings_limit)

    def _device_put(self, pol, pmask, src, dst, emask):
        sharding = jax.sharding.NamedSharding(
            self.mesh.mesh, self.mesh.spec_parts())
        return tuple(jax.device_put(jnp.asarray(x), sharding)
                     for x in (pol, pmask, src, dst, emask))

    def _save(self, root, level, levels, supports, pol, pmask, M, overflow,
              order):
        # invert the cumulative rebalance permutation: checkpoints hold
        # the OL store in canonical partition order (resume rebuilds the
        # edge-OL store canonically and must stay row-aligned)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        max_edges = max(len(c) for l in levels for c in l)
        state = {
            "levels": [[code_to_array(c, max_edges) for c in l]
                       for l in levels],
            "support_codes": [code_to_array(c, max_edges) for c in supports],
            "support_vals": np.asarray(list(supports.values()), np.int64),
            "pol": np.asarray(pol)[inv],
            "pmask": np.asarray(pmask)[inv],
            "max_embeddings": M,
            "total_overflow": overflow,
        }
        ckpt.save_step(root, level, state, metadata={"kind": "mirage-mining"})


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_f(a: np.ndarray, F: int, fill) -> np.ndarray:
    pad = F - a.shape[-1]
    if pad == 0:
        return a
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    return np.pad(a, widths, constant_values=fill)


def _imbalance(cost: np.ndarray, w: int) -> float:
    """max/mean of per-worker cost under the current blocked assignment."""
    per_worker = cost.reshape(w, -1).sum(-1)
    mean = per_worker.mean()
    return float(per_worker.max() / mean) if mean > 0 else 1.0


def _lpt_order(cost: np.ndarray, w: int) -> np.ndarray:
    """Re-pack partitions into w balanced blocks (LPT), then emit the
    permutation that lays blocks contiguously (matching the blocked
    dim-0 sharding)."""
    np_total = len(cost)
    per = np_total // w
    buckets: list[list[int]] = [[] for _ in range(w)]
    load = np.zeros(w)
    for i in np.argsort(-cost):
        # lightest bucket with room
        order = np.argsort(load)
        for b in order:
            if len(buckets[b]) < per:
                buckets[b].append(int(i))
                load[b] += cost[i]
                break
    return np.asarray([i for b in buckets for i in b], np.int32)
