"""Hill et al. [32]-style naive MapReduce FSM — the paper's comparison
baseline (Table III).

Deliberately reproduces the two deficiencies the paper calls out:

  1. **no duplicate elimination** — every generation path of a pattern is
     kept (no min-dfs-code canonicality test), so the candidate space and
     the emitted pattern set blow up exponentially with duplicates that
     a user must unify with their own isomorphism routine afterwards;
  2. **user-specified iteration count** — the loop runs exactly
     ``n_iterations`` regardless of when the frequent set empties.

Support counting still uses OL intersection so the comparison isolates
the algorithmic difference (candidate-space discipline), not data-plane
implementation details.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .candgen import EdgeAlphabet, Extension
from .dfscode import Code, code_to_graph, min_dfs_code, rightmost_path
from .graphdb import Graph
from .host_miner import (OccurrenceList, extend_ol, frequent_edges,
                         _single_edge_patterns)

__all__ = ["NaiveResult", "mine_naive"]


@dataclasses.dataclass
class NaiveResult:
    per_level_emitted: list[int]        # patterns emitted (with duplicates)
    per_level_candidates: list[int]     # candidates evaluated
    distinct_frequent: int              # after post-hoc unification
    duplicate_ratio: float              # emitted / distinct


@dataclasses.dataclass
class _Pat:
    code: Code          # generation-path code (NOT canonical)
    ol: OccurrenceList


def _all_extensions(code: Code, alphabet: EdgeAlphabet):
    """Every rightmost-path extension — *without* the canonicality test."""
    g = code_to_graph(code)
    rmp = rightmost_path(code)
    rmv = rmp[-1]
    vl = g.vlabels
    existing = {(min(int(u), int(v)), max(int(u), int(v))) for (u, v) in g.edges}
    out = []
    for w in rmp[:-1]:
        if (min(rmv, w), max(rmv, w)) in existing:
            continue
        for (e_lab, other) in alphabet.partners(int(vl[rmv])):
            if other == int(vl[w]):
                edge = (rmv, w, int(vl[rmv]), e_lab, int(vl[w]))
                out.append((code + (edge,),
                            Extension(False, rmv, w,
                                      (int(vl[rmv]), e_lab, int(vl[w])))))
    for w in rmp:
        for (e_lab, other) in alphabet.partners(int(vl[w])):
            edge = (int(w), g.n_vertices, int(vl[w]), e_lab, other)
            out.append((code + (edge,),
                        Extension(True, int(w), g.n_vertices,
                                  (int(vl[w]), e_lab, other))))
    return out


def mine_naive(graphs: Sequence[Graph], minsup: int,
               n_iterations: int) -> NaiveResult:
    alphabet, eocc = frequent_edges(graphs, minsup)
    f1 = _single_edge_patterns(alphabet, eocc, minsup)
    current = [_Pat(c, info.ol) for c, info in f1.items()]
    emitted = [len(current)]
    candidates = [len(current)]
    all_frequent_codes: list[Code] = [p.code for p in current]

    for _ in range(1, n_iterations):
        nxt: list[_Pat] = []
        n_cands = 0
        for p in current:
            for (child_code, ext) in _all_extensions(p.code, alphabet):
                n_cands += 1

                class _C:  # adapter for extend_ol's Candidate duck-type
                    pass
                c = _C()
                c.ext = ext
                col = extend_ol(p.ol, c, eocc)
                if len(col) >= minsup:
                    nxt.append(_Pat(child_code, col))
        candidates.append(n_cands)
        emitted.append(len(nxt))
        all_frequent_codes.extend(p.code for p in nxt)
        current = nxt
        if not current:
            break

    distinct = len({min_dfs_code(code_to_graph(c)) for c in all_frequent_codes})
    total = len(all_frequent_codes)
    return NaiveResult(emitted, candidates, distinct,
                       total / max(distinct, 1))
