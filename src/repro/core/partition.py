"""Data-partition phase (paper §IV-C.1).

Splits the transaction database into many partitions — deliberately far
more partitions than workers (paper Fig. 20: mapper cost is exponential
in partition size, shuffle cost only linear) — and strips globally
infrequent edges while doing so (paper Fig. 11).

Three schemes:
  scheme 1 — balance the number of graphs per partition (paper);
  scheme 2 — balance the total number of *edges* per partition (greedy
             LPT bin packing), the load-balancing win of Table IV (paper);
  "density" — balance edge DENSITY, à la Aridhi et al. (arXiv
             1212.0017): graphs sorted by density 2E/(V(V-1)) and
             snake-dealt across partitions, so the densest graphs — the
             ones whose embedding joins dominate map cost superlinearly
             in E — spread evenly instead of pooling in one LPT bin and
             serializing a shard.  Edge count is the tie-break within
             equal density, graph count the final tie-break.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .graphdb import Graph, validate_db
from .host_miner import frequent_edges
from .candgen import EdgeAlphabet

__all__ = ["PartitionResult", "filter_infrequent_edges", "graph_density",
           "make_partitions"]


@dataclasses.dataclass
class PartitionResult:
    partitions: list[list[Graph]]      # filtered graphs per partition
    graph_ids: list[list[int]]         # original indices (for support audit)
    alphabet: EdgeAlphabet             # global F_1 label triples
    minsup: int                        # absolute threshold
    n_graphs: int                      # original database size


def filter_infrequent_edges(
    graphs: Sequence[Graph], minsup: int
) -> tuple[list[Graph], EdgeAlphabet]:
    """Drop every edge whose label triple is globally infrequent."""
    alphabet, _ = frequent_edges(graphs, minsup)
    out = []
    for g in graphs:
        keep = np.zeros(g.n_edges, bool)
        for k, ((u, v), el) in enumerate(zip(g.edges, g.elabels)):
            t = (int(g.vlabels[u]), int(el), int(g.vlabels[v]))
            keep[k] = t in alphabet
        out.append(g.keep_edges(keep))
    return out, alphabet


def graph_density(g: Graph) -> float:
    """Undirected edge density 2E/(V(V-1)); a single-vertex (or empty)
    graph has density 0 by convention."""
    v = g.n_vertices
    return 0.0 if v < 2 else 2.0 * g.n_edges / (v * (v - 1))


def make_partitions(
    graphs: Sequence[Graph],
    minsup: int | float,
    n_partitions: int,
    *,
    scheme: int | str = 2,
) -> PartitionResult:
    """Filter + split.  ``minsup`` may be absolute (int) or a fraction.

    Raises when the split would leave partitions empty: an empty
    partition pads silently into the dense device encoding and wastes a
    worker slot — the caller (``Mirage.fit``) auto-clamps instead.  An
    EMPTY database is exempt (its partitions are necessarily empty;
    mining short-circuits to an empty result).
    """
    n = len(graphs)
    if n:
        # the load boundary: user input is validated HERE, before any
        # filtering (keep_edges legitimately empties graphs later).
        # An empty database stays exempt per the contract above.
        validate_db(graphs)
    if n_partitions < 1:
        raise ValueError(f"n_partitions={n_partitions} must be >= 1")
    if n and n_partitions > n:
        raise ValueError(
            f"n_partitions={n_partitions} exceeds the database size {n}: "
            f"every partition must hold at least one graph (clamp "
            f"n_partitions or pass more graphs)")
    abs_minsup = (int(np.ceil(minsup * n)) if isinstance(minsup, float)
                  else int(minsup))
    filtered, alphabet = filter_infrequent_edges(graphs, abs_minsup)

    ids = list(range(n))
    parts: list[list[int]] = [[] for _ in range(n_partitions)]
    if scheme == 1:
        for i in ids:
            parts[i % n_partitions].append(i)
    elif scheme == 2:
        load = np.zeros(n_partitions, np.int64)
        # LPT: heaviest graphs first onto the lightest partition;
        # ties (e.g. fully-filtered zero-edge graphs) break on graph
        # count so no partition is starved empty
        order = sorted(ids, key=lambda i: -filtered[i].n_edges)
        for i in order:
            p = min(range(n_partitions),
                    key=lambda b: (load[b], len(parts[b])))
            parts[p].append(i)
            load[p] += filtered[i].n_edges
    elif scheme == "density":
        # densest graphs first, snake-dealt (0..NP-1, NP-1..0, ...): each
        # pass hands every partition exactly one graph of comparable
        # density, and the direction flip cancels the within-pass bias —
        # graph counts stay balanced (|Δ| <= 1) by construction, so no
        # partition starves even when the DB is density-uniform
        order = sorted(ids, key=lambda i: (-graph_density(filtered[i]),
                                           -filtered[i].n_edges))
        for rank, i in enumerate(order):
            sweep, pos = divmod(rank, n_partitions)
            parts[pos if sweep % 2 == 0 else
                  n_partitions - 1 - pos].append(i)
    else:
        raise ValueError(f"unknown scheme {scheme!r} (1 | 2 | 'density')")

    return PartitionResult(
        partitions=[[filtered[i] for i in p] for p in parts],
        graph_ids=parts,
        alphabet=alphabet,
        minsup=abs_minsup,
        n_graphs=n,
    )
