"""Sequential baseline FSM algorithm (paper Fig. 3) — exact, host-side.

This is the in-memory algorithm MIRAGE distributes: breadth-first
candidate-generation-and-test with occurrence-list (OL) based support
counting (paper §IV-A.3).  It serves three roles here:

  1. the *baseline* the paper adapts (its Fig. 3), runnable as-is;
  2. the correctness oracle for the distributed engine and the kernels
     (exact, uncapped OLs, pure Python/numpy);
  3. the per-partition "local FSM" semantics reference: running it on a
     partition with ``minsup=1``-style non-zero-support retention yields
     exactly what a MIRAGE mapper chain would emit locally.

Patterns are keyed by min-dfs-code; OLs store *all* embeddings
(vertex-id tuples ordered by DFS id) per database graph.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .candgen import Candidate, EdgeAlphabet, generate_candidates
from .dfscode import Code, min_dfs_code
from .graphdb import Graph

__all__ = ["OccurrenceList", "PatternInfo", "MiningResult", "mine_host",
           "edge_occurrences", "frequent_edges"]


# OL: graph index -> list of embeddings; an embedding is a tuple of graph
# vertex ids, position = pattern DFS id.
OccurrenceList = dict[int, list[tuple[int, ...]]]


@dataclasses.dataclass
class PatternInfo:
    code: Code
    ol: OccurrenceList
    support: int


@dataclasses.dataclass
class MiningResult:
    frequent: dict[Code, PatternInfo]          # all levels merged
    levels: list[list[Code]]                   # frequent codes per level
    alphabet: EdgeAlphabet
    n_candidates: list[int]                    # per level, post-canonical
    n_raw_candidates: list[int] = dataclasses.field(default_factory=list)

    @property
    def codes(self) -> set[Code]:
        return set(self.frequent)


def edge_occurrences(graphs: Sequence[Graph]) -> dict[tuple[int, int, int], OccurrenceList]:
    """Directed edge occurrence lists per label triple (the partition-static
    *edge-OL* of paper Fig. 12b).  Triple (a, e, b) maps to (u, v) pairs
    with label(u)=a, elabel=e, label(v)=b — both orientations stored."""
    out: dict[tuple[int, int, int], OccurrenceList] = {}
    for gi, g in enumerate(graphs):
        for (u, v), el in zip(g.edges, g.elabels):
            lu, lv = int(g.vlabels[u]), int(g.vlabels[v])
            for (a, la, b, lb) in ((int(u), lu, int(v), lv),
                                   (int(v), lv, int(u), lu)):
                ol = out.setdefault((la, int(el), lb), {})
                ol.setdefault(gi, []).append((a, b))
    return out


def frequent_edges(
    graphs: Sequence[Graph], minsup: int
) -> tuple[EdgeAlphabet, dict[tuple[int, int, int], OccurrenceList]]:
    """F_1 in label-triple form + its occurrence lists (canonical a<=b)."""
    eocc = edge_occurrences(graphs)
    keep = []
    for (a, e, b), ol in eocc.items():
        if a <= b and len(ol) >= minsup:
            keep.append((a, e, b))
    alpha = EdgeAlphabet(keep)
    return alpha, {t: ol for t, ol in eocc.items()
                   if (min(t[0], t[2]), t[1], max(t[0], t[2])) in
                   {k for k in keep} | {(k[2], k[1], k[0]) for k in keep}}


def _single_edge_patterns(
    alphabet: EdgeAlphabet,
    eocc: dict[tuple[int, int, int], OccurrenceList],
    minsup: int,
) -> dict[Code, PatternInfo]:
    """F_1 as patterns: code ((0,1,a,e,b)) with a<=b; OL from edge-OL.

    For a == b both orientations of an occurrence are distinct embeddings.
    """
    out: dict[Code, PatternInfo] = {}
    for (a, e, b) in alphabet.canonical():
        code: Code = ((0, 1, a, e, b),)
        ol: OccurrenceList = {}
        for gi, occs in eocc.get((a, e, b), {}).items():
            ol[gi] = [tuple(p) for p in occs]
        sup = len(ol)
        if sup >= minsup:
            out[code] = PatternInfo(code, ol, sup)
    return out


def extend_ol(parent_ol: OccurrenceList, cand: Candidate,
              eocc: dict[tuple[int, int, int], OccurrenceList],
              max_embeddings: Optional[int] = None) -> OccurrenceList:
    """Child OL by parent-OL ⋈ edge-OL intersection (paper Fig. 6).

    This host routine is the semantic spec for the Pallas
    ``embedding_join`` kernel.
    """
    ext = cand.ext
    edge_ol = eocc.get(ext.triple, {})
    child: OccurrenceList = {}
    for gi, embs in parent_ol.items():
        occs = edge_ol.get(gi)
        if not occs:
            continue
        acc: list[tuple[int, ...]] = []
        for emb in embs:
            su = emb[ext.stub]
            if ext.forward:
                for (u, v) in occs:
                    if u == su and v not in emb:
                        acc.append(emb + (v,))
            else:
                tv = emb[ext.to]
                for (u, v) in occs:
                    if u == su and v == tv:
                        acc.append(emb)
                        break
        if acc:
            if max_embeddings is not None:
                acc = acc[:max_embeddings]
            child[gi] = acc
    return child


def mine_host(
    graphs: Sequence[Graph],
    minsup: int,
    *,
    max_size: Optional[int] = None,
) -> MiningResult:
    """The paper's Fig. 3 algorithm, exactly."""
    alphabet, eocc = frequent_edges(graphs, minsup)
    f1 = _single_edge_patterns(alphabet, eocc, minsup)
    frequent: dict[Code, PatternInfo] = dict(f1)
    levels: list[list[Code]] = [sorted(f1)]
    n_candidates: list[int] = [len(f1)]
    n_raw: list[int] = [len(f1)]

    current = {c: f1[c] for c in levels[0]}
    k = 1
    while current and (max_size is None or k < max_size):
        codes = sorted(current)
        cands = generate_candidates(codes, alphabet)
        n_candidates.append(len(cands))
        nxt: dict[Code, PatternInfo] = {}
        for cand in cands:
            parent = current[codes[cand.parent]]
            col = extend_ol(parent.ol, cand, eocc)
            sup = len(col)
            if sup >= minsup:
                nxt[cand.code] = PatternInfo(cand.code, col, sup)
        if not nxt:
            break
        levels.append(sorted(nxt))
        frequent.update(nxt)
        current = nxt
        k += 1
    return MiningResult(frequent, levels, alphabet, n_candidates, n_raw)
