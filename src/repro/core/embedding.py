"""Dense occurrence-list (OL) algebra — the device-side data plane.

MIRAGE's support counting is OL intersection (paper §IV-A.3, Fig. 6): the
child pattern's embeddings are the parent's embeddings joined with the
adjoined edge's occurrences.  Hadoop-MIRAGE does this in Java per mapper;
here it becomes fixed-shape masked tensor ops so a partition's whole
level-k state lives on a TPU core and the join runs on the VPU
(`kernels/embedding_join.py` is the tiled version; this module is the
pure-jnp reference/oracle and the shape contract).

Dense shapes for one partition (G graphs padded):

  edge-OL   : src/dst (T, G, F) int32 + mask (T, G, F) bool
              T = directed frequent label triples, F = max occ/graph
  level-k OL: ol (P, G, M, K) int32 + mask (P, G, M) bool
              P = |F_k| patterns, M = max embeddings/graph,
              K = k+1 (vertex-count pad; unused slots are -1)
  candidates: meta (C, 5) int32 rows [parent, stub, to, fwd, triple_idx]

Two-pass level execution (a beyond-paper optimization — Hadoop MIRAGE
materializes and *ships* OLs for every locally-non-zero candidate; we
materialize survivors only, locally):

  pass 1  local_supports()   -> (C,) per-graph-any popcount   [hot path]
  pass 2  materialize_ol()   -> compacted child OLs for frequent c only
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .candgen import Candidate
from .dfscode import Code
from .graphdb import Graph
from .host_miner import OccurrenceList

__all__ = [
    "EdgeOL", "LevelOL", "CandidateMeta",
    "build_edge_ol", "level1_ol", "candidate_meta",
    "join_valid", "local_supports_ref", "support_bits_ref",
    "materialize_one", "materialize_ol",
]

PAD = -1


@dataclasses.dataclass
class EdgeOL:
    """Partition-static directed edge occurrence lists (paper Fig. 12b)."""

    triples: np.ndarray    # (T, 3) int32 — the directed label-triple table
    src: np.ndarray        # (T, G, F) int32
    dst: np.ndarray        # (T, G, F) int32
    mask: np.ndarray       # (T, G, F) bool
    triple_index: dict[tuple[int, int, int], int]

    @property
    def shape(self):
        return self.src.shape


@dataclasses.dataclass
class LevelOL:
    """Stacked OLs for all frequent patterns of one level."""

    ol: jnp.ndarray        # (P, G, M, K) int32, PAD-filled
    mask: jnp.ndarray      # (P, G, M) bool

    @property
    def P(self):
        return self.ol.shape[0]


def build_edge_ol(
    graphs: Sequence[Graph],
    triples: Sequence[tuple[int, int, int]],
    *,
    pad_graphs: int | None = None,
    max_occ: int | None = None,
) -> EdgeOL:
    """Preparation-phase construction (host, once per partition).

    ``triples`` must be the *directed* closure of the frequent-edge
    alphabet so every partition indexes the same table (the shared key
    space that replaces Hadoop's shuffle-by-string-key).
    """
    tindex = {tuple(t): i for i, t in enumerate(triples)}
    G = pad_graphs or len(graphs)
    occs: list[list[list[tuple[int, int]]]] = [
        [[] for _ in range(G)] for _ in range(len(triples))]
    for gi, g in enumerate(graphs):
        for (u, v), el in zip(g.edges, g.elabels):
            lu, lv = int(g.vlabels[u]), int(g.vlabels[v])
            for (a, la, b, lb) in ((int(u), lu, int(v), lv),
                                   (int(v), lv, int(u), lu)):
                ti = tindex.get((la, int(el), lb))
                if ti is not None:
                    occs[ti][gi].append((a, b))
    F = max_occ or max((len(o) for row in occs for o in row), default=1)
    F = max(F, 1)
    T = len(triples)
    src = np.full((T, G, F), PAD, np.int32)
    dst = np.full((T, G, F), PAD, np.int32)
    mask = np.zeros((T, G, F), bool)
    for ti in range(T):
        for gi in range(G):
            o = occs[ti][gi][:F]
            if o:
                src[ti, gi, : len(o)] = [p[0] for p in o]
                dst[ti, gi, : len(o)] = [p[1] for p in o]
                mask[ti, gi, : len(o)] = True
    return EdgeOL(np.asarray(triples, np.int32), src, dst, mask, tindex)


def level1_ol(
    codes: Sequence[Code],
    eol: EdgeOL,
    *,
    max_embeddings: int,
) -> LevelOL:
    """F_1 OLs from the edge-OL (preparation phase's emitted patterns).

    A single-edge pattern (0,1,a,e,b) embeds at every directed occurrence
    of (a,e,b); when a == b the two orientations are distinct embeddings
    and already both present in the directed edge-OL.
    """
    P, M = len(codes), max_embeddings
    _, G, F = eol.src.shape
    ol = np.full((P, G, M, 2), PAD, np.int32)
    mask = np.zeros((P, G, M), bool)
    for pi, code in enumerate(codes):
        (i, j, a, e, b) = code[0]
        ti = eol.triple_index[(a, e, b)]
        take = min(M, F)
        ol[pi, :, :take, 0] = eol.src[ti, :, :take]
        ol[pi, :, :take, 1] = eol.dst[ti, :, :take]
        mask[pi, :, :take] = eol.mask[ti, :, :take]
    return LevelOL(jnp.asarray(ol), jnp.asarray(mask))


def candidate_meta(cands: Sequence[Candidate], eol: EdgeOL) -> np.ndarray:
    """(C, 5) int32: [parent, stub, to, fwd, triple_idx]."""
    rows = []
    for c in cands:
        rows.append([c.parent, c.ext.stub, c.ext.to, int(c.ext.forward),
                     eol.triple_index[c.ext.triple]])
    return np.asarray(rows, np.int32).reshape(-1, 5)


# ---------------------------------------------------------------------------
# Reference (pure-jnp) join — semantics oracle for the Pallas kernel
# ---------------------------------------------------------------------------

def join_valid(
    parent_ol: jnp.ndarray,   # (G, M, K)
    parent_mask: jnp.ndarray,  # (G, M)
    src: jnp.ndarray,          # (G, F)
    dst: jnp.ndarray,          # (G, F)
    emask: jnp.ndarray,        # (G, F)
    stub: jnp.ndarray,         # () int32
    to: jnp.ndarray,           # () int32
    forward: jnp.ndarray,      # () int32 (0/1)
) -> jnp.ndarray:
    """Valid-match mask (G, M, F): parent embedding m ⋈ edge occurrence f."""
    K = parent_ol.shape[-1]
    onehot = (jnp.arange(K) == stub).astype(parent_ol.dtype)
    stub_vals = (parent_ol * onehot).sum(-1)          # (G, M)
    hit = (src[:, None, :] == stub_vals[:, :, None])  # (G, M, F)
    hit &= parent_mask[:, :, None] & emask[:, None, :]

    # forward: new endpoint must not already be in the embedding
    member = (dst[:, None, :, None] == parent_ol[:, :, None, :]).any(-1)
    fwd_ok = ~member
    # backward: other endpoint must be exactly embedding[to]
    onehot_to = (jnp.arange(K) == to).astype(parent_ol.dtype)
    to_vals = (parent_ol * onehot_to).sum(-1)          # (G, M)
    bwd_ok = dst[:, None, :] == to_vals[:, :, None]
    return hit & jnp.where(forward.astype(bool), fwd_ok, bwd_ok)


def local_supports_ref(
    level: LevelOL,
    eol_src: jnp.ndarray, eol_dst: jnp.ndarray, eol_mask: jnp.ndarray,
    meta: jnp.ndarray,     # (C, 5)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-candidate local support (#graphs with >=1 match) and total
    embedding count (the straggler-rebalance cost signal).  Pure jnp.
    """
    def one(cand):
        parent, stub, to, fwd, tidx = cand[0], cand[1], cand[2], cand[3], cand[4]
        pol = jnp.take(level.ol, parent, axis=0)        # (G, M, K)
        pmask = jnp.take(level.mask, parent, axis=0)    # (G, M)
        src = jnp.take(eol_src, tidx, axis=0)           # (G, F)
        dst = jnp.take(eol_dst, tidx, axis=0)
        em = jnp.take(eol_mask, tidx, axis=0)
        valid = join_valid(pol, pmask, src, dst, em, stub, to, fwd)
        per_graph = valid.any(axis=(1, 2))
        return per_graph.sum(dtype=jnp.int32), valid.sum(dtype=jnp.int32)

    sup, cnt = jax.lax.map(one, meta)
    return sup, cnt


def support_bits_ref(
    meta: jnp.ndarray,     # (C, 5)
    pol: jnp.ndarray,      # (P, G, M, K)
    pmask: jnp.ndarray,    # (P, G, M)
    src: jnp.ndarray,      # (T, G, F)
    dst: jnp.ndarray,
    emask: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bitset-shaped support masks — the pure-jnp oracle for the packed
    fused kernel (DESIGN.md §12).

    Per candidate, the boolean per-graph verdict packs to a
    ``ceil(G/32)``-word uint32 bitset (LSB-first, pad bits zero) and
    local support is popcount over the words — bit-identical to
    ``local_supports_ref`` by construction.  Returns
    ``(sup (C,), emb (C,), vbits (C, ceil(G/32)))``.
    """
    from repro.kernels.bitset import pack_bits, popcount, tail_mask

    G = pol.shape[1]
    gmask = jnp.asarray(tail_mask(G))

    def one(cand):
        parent, stub, to, fwd, tidx = (cand[0], cand[1], cand[2], cand[3],
                                       cand[4])
        p = jnp.take(pol, parent, axis=0)
        pm = jnp.take(pmask, parent, axis=0).astype(bool)
        s = jnp.take(src, tidx, axis=0)
        d = jnp.take(dst, tidx, axis=0)
        em = jnp.take(emask, tidx, axis=0).astype(bool)
        valid = join_valid(p, pm, s, d, em, stub, to, fwd)
        bits = pack_bits(valid.any(axis=(1, 2))) & gmask
        return bits, valid.sum(dtype=jnp.int32)

    vbits, emb = jax.lax.map(one, meta)
    sup = popcount(vbits).sum(-1, dtype=jnp.int32)
    return sup, emb, vbits


def materialize_one(
    level: LevelOL,
    eol_src: jnp.ndarray, eol_dst: jnp.ndarray, eol_mask: jnp.ndarray,
    cand: jnp.ndarray,          # (5,) one candidate row
    *,
    max_embeddings: int,
    out_width: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Child OL of ONE candidate: (G, Mc, W) rows, (G, Mc) mask, and
    the scalar overflow (matches dropped by the Mc cap).  The single-slot
    building block: `materialize_ol` maps it over a survivor batch, and
    the level program (`core/level_step.py`) cond-gates it per compact
    slot so cap padding costs nothing.

    ``out_width`` is the child's vertex-slot width W (default K+1, the
    exact unbucketed growth).  Under shape bucketing the parent store is
    already wider than its real pattern, so W may equal K — the new
    vertex then lands in a slot that held PAD — and must never shrink
    below it."""
    G, M, K = level.ol.shape[1:]
    F = eol_src.shape[-1]
    Mc = max_embeddings
    W = K + 1 if out_width is None else out_width
    if W < K:
        raise ValueError(f"out_width={W} below parent vertex width {K}")

    parent, stub, to, fwd, tidx = (cand[0], cand[1], cand[2], cand[3],
                                   cand[4])
    pol = jnp.take(level.ol, parent, axis=0)
    pmask = jnp.take(level.mask, parent, axis=0)
    src = jnp.take(eol_src, tidx, axis=0)
    dst = jnp.take(eol_dst, tidx, axis=0)
    em = jnp.take(eol_mask, tidx, axis=0)
    valid = join_valid(pol, pmask, src, dst, em, stub, to, fwd)  # (G,M,F)

    # child embedding (m, f): parent row m extended by dst[f] (forward)
    # or unchanged (backward).  Backward duplicates (same m, several f)
    # are collapsed to the first f per m.
    first_f = (jnp.cumsum(valid, axis=-1) == 1) & valid
    vsel = jnp.where(fwd.astype(bool), valid, first_f)           # (G,M,F)

    flat = vsel.reshape(G, M * F)
    # stable compaction: output slot r holds the index of the (r+1)-th
    # valid entry of its graph row — a vectorized binary search over the
    # prefix sums.  Entries ranked past the Mc cap (and all invalid
    # entries) are masked off by ``picked``.  Replaces the earlier
    # rank->index scatter, which XLA lowers serially (measured ~4x
    # slower than the search on CPU).
    csum = jnp.cumsum(flat, axis=-1)                             # (G,MF)
    tgt = jnp.arange(1, Mc + 1)
    order = jax.vmap(lambda row: jnp.searchsorted(row, tgt))(csum)
    order = jnp.minimum(order, M * F - 1).astype(jnp.int32)      # (G,Mc)
    n_valid = csum[:, -1]                                        # (G,)
    picked = jnp.arange(Mc)[None, :] < n_valid[:, None]          # (G,Mc)
    m_idx, f_idx = order // F, order % F

    par_rows = jnp.take_along_axis(
        pol, m_idx[:, :, None], axis=1)                          # (G,Mc,K)
    new_v = jnp.take_along_axis(dst, f_idx, axis=-1)             # (G,Mc)
    # Pad to W slots, then scatter the new vertex at its DFS id
    # (= ext.to for forward edges; patterns with back edges have
    # n_v < K so the write position is NOT necessarily the last slot).
    # Under bucketing W may equal K: the parent slot at ``to`` is PAD
    # (the parent pattern has fewer than K real vertices), so the
    # overwrite is always into a free slot.
    if W > K:
        child = jnp.concatenate(
            [par_rows,
             jnp.full(par_rows.shape[:-1] + (W - K,), PAD,
                      par_rows.dtype)], axis=-1)
    else:
        child = par_rows
    slot = jnp.arange(W) == to                                   # (W,)
    child = jnp.where(slot[None, None, :] & fwd.astype(bool),
                      new_v[:, :, None], child)                  # (G,Mc,W)
    child = jnp.where(picked[:, :, None], child, PAD)
    overflow = (vsel.sum(dtype=jnp.int32)
                - picked.sum(dtype=jnp.int32))
    return child.astype(jnp.int32), picked, overflow


def materialize_ol(
    level: LevelOL,
    eol_src: jnp.ndarray, eol_dst: jnp.ndarray, eol_mask: jnp.ndarray,
    meta: jnp.ndarray,          # (C', 5) — surviving candidates only
    *,
    max_embeddings: int,
    out_width: int | None = None,
) -> tuple[LevelOL, jnp.ndarray]:
    """Compacted child OLs for the surviving candidates (pass 2).

    Returns the next LevelOL (``out_width`` vertex slots, default K+1)
    and the per-candidate overflow count (matches dropped by the M cap
    — exactness telemetry).
    """
    child, mask, over = jax.lax.map(
        lambda cand: materialize_one(level, eol_src, eol_dst, eol_mask,
                                     cand, max_embeddings=max_embeddings,
                                     out_width=out_width),
        meta)
    return LevelOL(child, mask), over
