"""Whole-run device-resident mining loop (pipeline="device_loop",
DESIGN.md §13).

The single-sync pipeline (PR 3) already collapsed each mining level to
one jitted program and ONE device→host transfer — but the *run* still
crossed the boundary once per level: fetch the wire, host-generate the
next level's candidates, re-upload their metadata, dispatch again.  On
a real pod every crossing is a dispatch-latency bubble; on the paper's
ledger it is the per-iteration job-startup overhead of iterative
MapReduce (§IV-B), shrunk but not gone.

This module removes the loop itself from the host.  One jitted
shard_map program executes the ENTIRE run as a ``lax.while_loop``:

  body (one level, all on device):
    1. candidate generation — ``candgen.device_candidates``: rightmost-
       path extension slots over array-shaped DFS codes + the bounded-
       state ``min_dfs_canonical_array`` canonicality machine, prefix-sum
       compacted into a fixed candidate budget CB in EXACTLY the host
       generator's order;
    2. schedule — ``candgen.device_schedule`` recasts the parent-grouped
       tile schedule as pure jnp under static (rows, tile_c), feeding
       the fused Pallas kernel inside the loop body (non-fused backends
       take the vmapped ``device_local_supports`` path);
    3. map + shuffle — the same ``reduce_supports`` collective as the
       level program (psum | reduce_scatter, bit-packed verdict lanes
       under ``packed``), with the support vector all-gathered so every
       device can fill the run outputs;
    4. reduce — verdict-masked prefix-sum compaction of survivors into
       the SPP parent slots, cond-gated ``materialize_one`` per slot;
    5. bookkeeping — per-level stats row (candidates, survivors,
       overflow, imbalance, bail flags), survivor supports and codes
       written at the level's slot of the run outputs.

  cond: ``(k < k_stop) & (n_par > 0) & ok`` — mining stops at max_size,
  at the first empty frequent set, or when any exactness valve trips
  (candidate/state/schedule budget overflow); ``ok=False`` makes the
  driver fall back to the per-level single-sync pipeline, keeping the
  conformance contract bit-exact.

Every iteration has IDENTICAL shapes (the while_loop carry): the run
compiles ONE program (asserted ≤3 in tests/test_compile_cache.py) and
the host receives ONE transfer — the run wire:

  [ out_stats (NL·6) | out_sups (NL·SPP) | out_codes (NL·SPP·L·5)
    | k_final | n_par | ok | total_overflow | checksum ]

verified with the §10 position-salted checksum and decoded into the
same levels/supports/stats the per-level pipeline produces.

Checkpoint cadence (``device_loop_ckpt_every``): the SAME compiled
program is re-invoked on its own device-resident carry with a nearer
``k_stop`` — a chunk; at each chunk boundary the host fetches the wire
plus the OL store and writes the usual canonical checkpoint.  The
transfer count per run is exactly ``1`` without checkpointing and
``3 · n_chunks`` with it (wire + pol + pmask per boundary), gated by
``benchmarks/check_residency.py``.

The escalation valve hoists to run granularity: the loop mines at one
uniform embedding cap M (the carry shape); if the run finishes with
``total_overflow > 0`` the driver doubles M and reruns the whole
program — earlier levels had no overflow at the smaller M, so their
stores are bit-identical at the larger one and the rerun converges to
the exact (escalated) host semantics.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import (Backend, device_local_supports,
                           fused_level_supports,
                           fused_level_supports_packed, is_fused_backend)
from ..runtime import jax_compat
from .candgen import device_candidates, device_schedule
from .embedding import LevelOL, materialize_one
from .level_step import _IMBAL_FX, wire_checksum
from .mapreduce import MiningMesh, reduce_supports, worker_imbalance

__all__ = ["DeviceLoopFallback", "RunWire", "run_wire_words",
           "decode_run_wire", "run_program"]

#: per-level stats words in the run wire:
#: [n_candidates, n_keep, overflow, imbalance·2^16, bail flags, reserved]
NSTAT = 6

#: bail-flag bits (stats word 4): any nonzero flag stops the loop and
#: sends the driver to the single-sync fallback
FLAG_RAW_OVF = 1        # structural slots overflowed the raw budget
FLAG_CANON_OVF = 2      # canonical candidates overflowed CB
FLAG_STATE_OVF = 4      # canonicality machine overflowed max_states
FLAG_SCHED_OVF = 8      # tile-padded schedule overflowed the row budget


class DeviceLoopFallback(RuntimeError):
    """The device loop bailed (budget/state/schedule overflow, or
    overflow at the M ceiling) — the driver replays the run through the
    per-level single-sync pipeline, which has no static budgets."""


@dataclasses.dataclass
class RunWire:
    """Host view of the run's single transfer."""

    stats: np.ndarray      # (NL, NSTAT) int32 per-level stats rows
    sups: np.ndarray       # (NL, SPP) int32 survivor supports, slot order
    codes: np.ndarray      # (NL, SPP, L, 5) int32 survivor DFS codes
    k_final: int           # parent size the loop stopped at
    n_par: int             # surviving parent count at the stop
    ok: bool               # False = a bail flag tripped mid-run
    total_overflow: int    # M-cap overflow summed over the run


def run_wire_words(n_levels: int, spp: int, max_edges: int) -> int:
    """Total int32 words of the run wire (incl. trailer + checksum)."""
    return (n_levels * NSTAT + n_levels * spp
            + n_levels * spp * max_edges * 5 + 4 + 1)


def decode_run_wire(body: np.ndarray, n_levels: int, spp: int,
                    max_edges: int) -> RunWire:
    """Decode a (checksum-stripped) run-wire body by explicit offsets."""
    o = 0
    stats = body[o:o + n_levels * NSTAT].reshape(n_levels, NSTAT)
    o += n_levels * NSTAT
    sups = body[o:o + n_levels * spp].reshape(n_levels, spp)
    o += n_levels * spp
    codes = body[o:o + n_levels * spp * max_edges * 5].reshape(
        n_levels, spp, max_edges, 5)
    o += n_levels * spp * max_edges * 5
    k_final, n_par, ok, tovf = (int(x) for x in body[o:o + 4])
    return RunWire(stats, sups, codes, k_final, n_par, bool(ok), tovf)


@functools.lru_cache(maxsize=32)
def _run_program(mmesh: MiningMesh, minsup: int, backend: Backend,
                 reduce: str, packed: bool, max_edges: int,
                 n_vertex_slots: int, c_budget: int, raw_budget: int,
                 max_states: int, n_levels: int, tile_c: int,
                 sched_rows: int, n_triples: int, unroll: int):
    """Build (once per static config) the jitted whole-run program.

    ``k_stop`` and the loop carry are TRACED — chunked re-invocation for
    checkpointing reuses this one compile.  ``unroll > 0`` replaces the
    while_loop with that many cond-gated body applications (the
    stepping-stone variant differential tests pin against the loop).
    All shapes are static: CB (``c_budget``) is the canonical candidate
    budget, CBR the structural raw budget, SPP the parent/survivor slot
    count (the codes/OL-store pattern axis), NL the level-slot count,
    and the fused schedule lives in ``sched_rows`` rows of ``tile_c``.
    """
    axes = mmesh.axes
    W = mmesh.n_workers
    parts = mmesh.spec_parts()
    rep = mmesh.replicated()
    fused = is_fused_backend(backend)
    interpret = backend.endswith("interpret")
    NV = n_vertex_slots
    CB = c_budget
    NL = n_levels

    def core(k_stop, k0, n_par0, codes0, triples, pol, pmask, src, dst,
             emask, out_codes0, out_sups0, out_stats0, ok0, tovf0):
        SPP = codes0.shape[0]
        PP, _, G, M, K = pol.shape

        def body(carry):
            (k, n_par, codes, pol, pmask,
             out_codes, out_sups, out_stats, ok, tovf) = carry

            # 1. right-most-extension candidates, host order (candgen.py)
            meta, child, n_cand, cg_flags = device_candidates(
                codes, n_par, triples, n_vertex_slots=NV,
                raw_budget=raw_budget, budget=CB, max_states=max_states)

            # 2+3. map phase + shuffle — same kernels/collective as the
            # per-level program, with the schedule built on device
            if fused:
                sched, tiles, inv, sc_ovf = device_schedule(
                    meta, n_cand, tile_c=tile_c, n_triples=n_triples,
                    rows=sched_rows)
                if packed:
                    sup_pp, emb_s, _vbits = fused_level_supports_packed(
                        sched, tiles, pol, pmask, src, dst, emask,
                        interpret=interpret)
                else:
                    sup_pp, emb_s = fused_level_supports(
                        sched, tiles, pol, pmask, src, dst, emask,
                        interpret=interpret)
                local_sup = jnp.take(sup_pp.sum(0), inv)     # (CB,) canonical
                emb_pp = jnp.take(emb_s, inv, axis=1)        # (PP, CB)
            else:
                local_sup, _, emb_pp = device_local_supports(
                    meta, pol, pmask, src, dst, emask, backend=backend,
                    packed=packed)
                sc_ovf = jnp.zeros((), bool)
            # the run outputs need the full support vector on every
            # device, so the sharded-gsup wire optimization does not
            # apply here — there is only ONE transfer per run anyway
            gsup, verdict = reduce_supports(local_sup, axes, minsup,
                                            reduce, gather_gsup=True,
                                            packed=packed)

            # 4. survivor compaction into the SPP parent slots (the
            # level program's prefix-sum idiom; SPP >= CB >= n_keep, so
            # the compaction can never miss)
            real = jnp.arange(CB) < n_cand
            keep = (verdict != 0) & real
            rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
            n_keep = rank[-1] + 1
            dest = jnp.where(keep, rank, SPP)
            surv = (jnp.zeros((SPP,), jnp.int32)
                    .at[dest].set(jnp.arange(CB, dtype=jnp.int32),
                                  mode="drop"))
            valid_s = jnp.arange(SPP) < n_keep
            cmeta = jnp.take(meta, surv, axis=0)             # (SPP, 5)

            def per_slot(slot):
                cand, valid = slot

                def do(_):
                    ch, mk, over = jax.vmap(
                        lambda po, pm, s, d, e: materialize_one(
                            LevelOL(po, pm), s, d, e, cand,
                            max_embeddings=M, out_width=K)
                    )(pol, pmask, src, dst, emask)
                    return ch, mk, over.sum()

                def skip(_):
                    return (jnp.full((PP, G, M, K), -1, jnp.int32),
                            jnp.zeros((PP, G, M), bool),
                            jnp.zeros((), jnp.int32))

                return jax.lax.cond(valid, do, skip, None)

            ol_s, mask_s, over_s = jax.lax.map(per_slot, (cmeta, valid_s))
            new_pol = jnp.moveaxis(ol_s, 0, 1)       # (PP, SPP, G, M, K)
            new_pmask = jnp.moveaxis(mask_s, 0, 1)
            overflow = jax.lax.psum(over_s.sum(), axes)

            # 5. run-output bookkeeping at this level's slot
            cost_pp = (emb_pp * real[None, :].astype(emb_pp.dtype)).sum(1)
            cost = jax.lax.all_gather(cost_pp, axes, axis=0, tiled=True)
            imbal = worker_imbalance(cost, W)
            flags = (cg_flags[0].astype(jnp.int32) * FLAG_RAW_OVF
                     | cg_flags[1].astype(jnp.int32) * FLAG_CANON_OVF
                     | cg_flags[2].astype(jnp.int32) * FLAG_STATE_OVF
                     | sc_ovf.astype(jnp.int32) * FLAG_SCHED_OVF)
            slot = k - 1
            out_stats = out_stats.at[slot].set(jnp.stack(
                [n_cand, n_keep, overflow,
                 (imbal * _IMBAL_FX).astype(jnp.int32), flags,
                 jnp.zeros((), jnp.int32)]))
            out_sups = out_sups.at[slot].set(
                jnp.where(valid_s, jnp.take(gsup, surv), 0)
                .astype(jnp.int32))
            new_codes = jnp.where(valid_s[:, None, None],
                                  jnp.take(child, surv, axis=0), -1)
            out_codes = out_codes.at[slot].set(new_codes)
            return (k + 1, n_keep, new_codes, new_pol, new_pmask,
                    out_codes, out_sups, out_stats,
                    ok & (flags == 0), tovf + overflow)

        def cond(carry):
            k, n_par = carry[0], carry[1]
            ok = carry[8]
            return (k < k_stop) & (n_par > 0) & ok

        carry = (k0, n_par0, codes0, pol, pmask,
                 out_codes0, out_sups0, out_stats0, ok0, tovf0)
        if unroll > 0:
            for _ in range(unroll):
                carry = jax.lax.cond(cond(carry), body, lambda c: c, carry)
        else:
            carry = jax.lax.while_loop(cond, body, carry)
        (k, n_par, codes, pol, pmask,
         out_codes, out_sups, out_stats, ok, tovf) = carry

        wire_body = jnp.concatenate([
            out_stats.reshape(-1), out_sups.reshape(-1),
            out_codes.reshape(-1),
            jnp.stack([k, n_par, ok.astype(jnp.int32), tovf])])
        wire = jnp.concatenate([wire_body, wire_checksum(wire_body)[None]])
        return (wire, k, n_par, codes, pol, pmask,
                out_codes, out_sups, out_stats, ok, tovf)

    smapped = jax_compat.shard_map(
        core, mesh=mmesh.mesh,
        in_specs=(rep, rep, rep, rep, rep, parts, parts, parts, parts,
                  parts, rep, rep, rep, rep, rep),
        out_specs=(rep, rep, rep, rep, parts, parts, rep, rep, rep, rep,
                   rep),
        check_vma=False)
    return jax.jit(smapped)


def run_program(*args, **kwargs):
    """Public (monkeypatch-stable) accessor for the cached run program —
    the compile-count tracer in tests wraps ``_run_program`` exactly the
    way it wraps ``level_step._level_program``."""
    return _run_program(*args, **kwargs)
