"""MIRAGE core: the paper's algorithm (host-exact + distributed)."""
from .candgen import Candidate, EdgeAlphabet, generate_candidates
from .dfscode import Code, is_canonical, min_dfs_code, rightmost_path
from .graphdb import Graph, paper_toy_db, pubchem_like_db, random_db
from .host_miner import mine_host
from .mapreduce import MiningMesh
from .mining import DistMiningResult, Mirage, MirageConfig
from .naive import mine_naive
from .partition import make_partitions

__all__ = [
    "Candidate", "EdgeAlphabet", "generate_candidates", "Code",
    "is_canonical", "min_dfs_code", "rightmost_path", "Graph",
    "paper_toy_db", "pubchem_like_db", "random_db", "mine_host",
    "MiningMesh", "DistMiningResult", "Mirage", "MirageConfig",
    "mine_naive", "make_partitions",
]
