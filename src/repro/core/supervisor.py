"""Supervised recovery driver around :meth:`Mirage.mine` (DESIGN.md §10, §14).

MIRAGE inherits MapReduce's contract: iterations are restartable because
level state hits durable storage between them, so the *job* survives
what kills a *task*.  This module is that job-level supervisor for the
JAX runtime.  It classifies every failure the mining loop can surface —
injected or real — and applies one of five recoveries:

  worker_loss  → elastically shrink the worker pool (largest divisor of
                 n_partitions below the current W, floored at
                 ``min_workers``) and resume from the latest intact
                 checkpoint; PR 2's canonical unsharded checkpoints make
                 the re-layout free.  When no smaller mesh exists the
                 level is simply replayed on the same mesh.
  kernel       → retry; after ``degrade_after`` kernel faults descend
                 the degradation ladder ``fused → pallas → legacy``
                 (rung 1 swaps the fused single-launch kernel for the
                 two-launch pallas/interpret backend; rung 2 abandons
                 the single-sync program for the legacy host-driven
                 pipeline, which dispatches no fused kernel at all).
                 A ``pipeline="device_loop"`` run gets one extra rung
                 FIRST: abandon the whole-run loop for the per-level
                 single-sync program, which re-syncs (and re-checks)
                 every level instead of once per run.
  transient    → (wire checksum failures and other flaky-link signals)
                 retry with exponential backoff, same configuration.
  state        → (checkpoint integrity, audit failures) retry: the
                 store has already reaped the corrupt step, so the next
                 attempt resumes from the newest *intact* one — or
                 restarts clean.
  hang         → (watchdog-detected stalled phase, DESIGN.md §14) a
                 device_loop run descends its single_sync rung
                 immediately — the per-level program re-syncs every
                 level, bounding any future stall to one level; other
                 pipelines replay from the newest checkpoint.

Anything unclassified is **fatal** and re-raised untouched: a
supervisor that swallows real bugs would poison every chaos guarantee.

**Unified retry budget** (§14): every recovery class draws from ONE
jittered-exponential-backoff :class:`RetryBudget`, so a fault storm of
mixed kinds cannot loop forever.  Budget exhaustion — like a run
deadline (:class:`~repro.runtime.faults.DeadlineExceeded`, never
retried) — routes into the **anytime contract**: with
``on_exhausted="partial"`` the supervisor returns a
:class:`~repro.core.mining.PartialResult` cut at the newest intact
*audited* checkpoint (re-verified through
``auditor.audit_frequent_set`` before it is trusted) instead of
raising; ``"raise"`` (the default) preserves the strict behavior.

Every decision is recorded as a structured :class:`FaultEvent` and —
crash-safely — appended to ``fault_log_path`` as one JSON line per
event the moment it happens (a hard kill still leaves a usable log);
an end-of-run summary line closes the file.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from ..runtime import checkpoint as ckpt
from ..runtime import faults, jax_compat
from ..runtime.watchdog import Watchdog
from .auditor import audit_frequent_set
from .graphdb import Graph
from .mapreduce import MiningMesh
from .mining import (DistMiningResult, Mirage, MirageConfig,
                     PartialResult, decode_saved_levels)

__all__ = ["SupervisorConfig", "FaultEvent", "MiningSupervisor",
           "RetryBudget", "classify", "elastic_shrink", "ladder_for"]

#: degradation-ladder rungs, most- to least-accelerated.  Each entry is
#: the config override applied at that rung; rung 0 is "as configured".
LADDER = ("as-configured", "pallas", "legacy")

#: the device-loop pipeline descends one extra rung first: give up the
#: whole-run while_loop for the per-level single-sync program (same
#: kernels, but a host sync — and a fresh chance — every level)
DEVICE_LOOP_LADDER = ("as-configured", "single_sync", "pallas", "legacy")


def ladder_for(cfg: MirageConfig) -> tuple[str, ...]:
    """The degradation ladder the ORIGINAL config starts from."""
    return (DEVICE_LOOP_LADDER if cfg.pipeline == "device_loop"
            else LADDER)


def classify(exc: BaseException) -> Optional[str]:
    """Map an exception to a recovery class, or None for fatal."""
    if isinstance(exc, faults.WorkerLost):
        return "worker_loss"
    if isinstance(exc, faults.KernelFault):
        return "kernel"
    if isinstance(exc, faults.HangTimeout):
        return "hang"
    if isinstance(exc, faults.WireIntegrityError):
        return "transient"
    if isinstance(exc, (faults.CheckpointIntegrityError,
                        faults.AuditError)):
        return "state"
    return None


def elastic_shrink(workers: int, n_partitions: int,
                   min_workers: int = 1) -> Optional[int]:
    """Largest viable worker count below ``workers``: the partition
    count must stay divisible (blocked dim-0 sharding), so this is the
    largest divisor of ``n_partitions`` in [min_workers, workers)."""
    for w in range(workers - 1, min_workers - 1, -1):
        if n_partitions % w == 0:
            return w
    return None


@dataclasses.dataclass
class RetryBudget:
    """One unified retry budget shared by every recovery class.

    ``spend(kind)`` charges one attempt and returns the jittered
    exponential backoff to sleep — or None when the budget is
    exhausted, which is exactly what routes the supervisor into the
    partial-result path.  Jitter is seeded (deterministic chaos runs):
    ``backoff = min(base·factor^(n-1), cap) · (1 + jitter·u)``,
    u ~ U[0, 1)."""

    max_attempts: int = 5
    base: float = 0.05
    factor: float = 2.0
    cap: float = 2.0
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        self.attempt = 0
        self.by_kind: dict = {}
        self._rng = np.random.default_rng(self.seed)

    @property
    def exhausted(self) -> bool:
        return self.attempt >= self.max_attempts

    def spend(self, kind: str) -> Optional[float]:
        if self.exhausted:
            return None
        self.attempt += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0) + 1
        backoff = min(self.base * self.factor ** (self.attempt - 1),
                      self.cap)
        if backoff > 0 and self.jitter > 0:
            backoff *= 1.0 + self.jitter * float(self._rng.random())
        return backoff


@dataclasses.dataclass
class SupervisorConfig:
    max_retries: int = 5                # unified retry budget
    backoff_base: float = 0.05          # seconds before attempt 2
    backoff_factor: float = 2.0
    backoff_max: float = 2.0
    backoff_jitter: float = 0.25        # jitter fraction on each backoff
    seed: int = 0                       # jitter rng seed (determinism)
    degrade_after: int = 2              # kernel faults per ladder rung
    min_workers: int = 1                # elastic-shrink floor
    deadline_s: Optional[float] = None  # whole-run wall-clock budget
    on_exhausted: str = "raise"         # "raise" | "partial" (DESIGN §14)
    sleep_fn: Callable[[float], None] = time.sleep
    fault_log_path: Optional[str] = None

    def __post_init__(self):
        if self.on_exhausted not in ("raise", "partial"):
            raise ValueError(
                f"on_exhausted must be 'raise' or 'partial', "
                f"got {self.on_exhausted!r}")


@dataclasses.dataclass
class FaultEvent:
    """One supervisor decision, structured for the fault log."""

    attempt: int
    kind: str                           # recovery class (or "fatal")
    error: str                          # repr of the triggering exception
    level: Optional[int]                # mining level, when known
    action: str                         # retry | shrink | degrade |
    detail: str                         #   partial | give_up
    backoff: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class MiningSupervisor:
    """Run :meth:`Mirage.mine` to completion through faults.

    ``mesh`` seeds the worker pool (default single-device);
    ``mesh_factory(n_workers)`` builds the shrunken mesh on worker loss
    — the default takes the first n of ``jax.devices()``.  Recovery is
    only cheap with ``config.checkpoint_dir`` set (resume replays at
    most one level); without it every retry restarts from scratch,
    which is still correct, just slower.  ``watchdog`` injects a
    pre-built :class:`Watchdog` (tests pin ``phase_default`` for
    deterministic hang detection); by default one is built from
    ``deadline_s`` + the config's phase-deadline knobs and spans every
    retry — the run deadline is wall-clock, not per-attempt.
    """

    def __init__(self, config: MirageConfig,
                 sup: Optional[SupervisorConfig] = None,
                 mesh: Optional[MiningMesh] = None,
                 mesh_factory: Optional[Callable[[int], MiningMesh]] = None,
                 watchdog: Optional[Watchdog] = None):
        self.config = config
        self.sup = sup or SupervisorConfig()
        self.mesh = mesh or MiningMesh.single_device()
        self.mesh_factory = mesh_factory or _default_mesh_factory
        self.events: list[FaultEvent] = []
        self.audit_report: list[dict] = []
        self.rung = 0
        self.watchdog = watchdog
        self.budget: Optional[RetryBudget] = None
        self.last_miner: Optional[Mirage] = None
        self._log_open = False

    # ------------------------------------------------------------------
    def mine(self, graphs: Sequence[Graph], *, resume: bool = False,
             deadline_s: Optional[float] = None
             ) -> Union[DistMiningResult, PartialResult]:
        sup = self.sup
        cfg = self.config
        mesh = self.mesh
        ladder = ladder_for(cfg)
        deadline = deadline_s if deadline_s is not None else sup.deadline_s
        wd = self.watchdog
        if wd is None:
            wd = Watchdog(run_deadline_s=deadline,
                          phase_floor=cfg.level_deadline_floor,
                          phase_slack=cfg.level_deadline_slack,
                          on_trip=self._log_line)
        elif wd.on_trip is None:
            wd.on_trip = self._log_line
        self.watchdog = wd
        wd.start()
        budget = self.budget = RetryBudget(
            max_attempts=sup.max_retries, base=sup.backoff_base,
            factor=sup.backoff_factor, cap=sup.backoff_max,
            jitter=sup.backoff_jitter, seed=sup.seed)
        kernel_faults = 0
        try:
            while True:
                miner = Mirage(cfg, mesh)
                self.last_miner = miner
                try:
                    result = miner.mine(
                        graphs, resume=resume or budget.attempt > 0,
                        watchdog=wd)
                    self._finish_log("complete")
                    return result
                except faults.DeadlineExceeded as exc:
                    # never retried: the clock cannot be argued with
                    partial = sup.on_exhausted == "partial"
                    self._record(budget.attempt, "deadline", exc,
                                 "partial" if partial else "give_up",
                                 "run deadline exceeded — cutting at the "
                                 "newest audited checkpoint"
                                 if partial else
                                 "run deadline exceeded", 0.0)
                    if partial:
                        return self._partial(cfg, "deadline")
                    self._finish_log("deadline")
                    raise
                except Exception as exc:                  # noqa: BLE001
                    kind = classify(exc)
                    if kind is None:
                        self._record(budget.attempt, "fatal", exc,
                                     "give_up",
                                     "unclassified failure — re-raised",
                                     0.0)
                        self._finish_log("fatal")
                        raise
                    backoff = budget.spend(kind)
                    if backoff is None:
                        partial = sup.on_exhausted == "partial"
                        self._record(
                            budget.attempt, kind, exc,
                            "partial" if partial else "give_up",
                            f"retry budget ({sup.max_retries}) "
                            f"exhausted", 0.0)
                        if partial:
                            return self._partial(cfg, "budget-exhausted")
                        self._finish_log("exhausted")
                        raise
                    action, detail = "retry", "same configuration"

                    if kind == "worker_loss":
                        w = elastic_shrink(mesh.n_workers,
                                           cfg.n_partitions,
                                           sup.min_workers)
                        if w is not None:
                            mesh = self.mesh_factory(w)
                            action = "shrink"
                            detail = (f"elastic shrink to {w} worker(s), "
                                      f"resume from checkpoint")
                        else:
                            detail = (f"no viable mesh below "
                                      f"{mesh.n_workers} worker(s) — "
                                      f"replay on the same mesh")
                    elif kind == "kernel":
                        kernel_faults += 1
                        if (kernel_faults % sup.degrade_after == 0
                                and self.rung < len(ladder) - 1):
                            self.rung += 1
                            cfg = _degrade(cfg, ladder[self.rung])
                            action = "degrade"
                            detail = (f"descend ladder to rung "
                                      f"{self.rung} "
                                      f"({ladder[self.rung]})")
                    elif kind == "hang":
                        waited = getattr(exc, "waited_s", 0.0)
                        if (cfg.pipeline == "device_loop"
                                and self.rung < len(ladder) - 1):
                            # a stalled chunk forfeits the whole-run
                            # loop: the single-sync rung re-syncs every
                            # level, bounding any future stall
                            self.rung = max(self.rung, 1)
                            cfg = _degrade(cfg, ladder[self.rung])
                            action = "degrade"
                            detail = (f"stalled device_loop chunk "
                                      f"(detected after {waited:.2f}s) — "
                                      f"descend to "
                                      f"{ladder[self.rung]}")
                        else:
                            detail = (f"stalled phase detected after "
                                      f"{waited:.2f}s — replay from "
                                      f"newest checkpoint")
                    elif kind == "state":
                        detail = ("corrupt or audit-failed state — "
                                  "resume from newest intact audited "
                                  "step (or restart clean)")

                    self._record(budget.attempt, kind, exc, action,
                                 detail, backoff)
                    rem = wd.run_remaining()
                    if rem is not None and rem <= 0:
                        continue          # let the deadline path fire
                    if backoff > 0:
                        if rem is not None:
                            backoff = min(backoff, max(rem, 0.0))
                        sup.sleep_fn(backoff)
        finally:
            if self.last_miner is not None and self.last_miner.auditor:
                self.audit_report.extend(self.last_miner.auditor.report)

    # ------------------------------------------------------------------
    def _partial(self, cfg: MirageConfig, reason: str) -> PartialResult:
        """Cut a verified partial result at the newest intact *audited*
        checkpoint: load (digest-verified), decode, and re-audit the
        whole frequent-set prefix before trusting it.  With no surviving
        checkpoint the result is the (trivially valid) empty prefix."""
        levels: list = []
        supports: dict = {}
        last_level, audited, minsup = 0, False, None
        if cfg.checkpoint_dir:
            for step in sorted(ckpt.all_steps(cfg.checkpoint_dir),
                               reverse=True):
                path = os.path.join(cfg.checkpoint_dir,
                                    f"step_{step:010d}")
                try:
                    state, meta = ckpt.load_pytree(path)
                except Exception:
                    continue              # corrupt/unreadable: skip down
                if not meta.get("audited"):
                    continue              # only ever cut at audited levels
                try:
                    lv, sp = decode_saved_levels(state)
                    ms = meta.get("minsup")
                    audit_frequent_set(lv, sp, ms,
                                       n_graphs=meta.get("n_graphs", -1))
                except Exception:
                    continue              # failed re-audit: keep walking
                levels, supports = lv, sp
                last_level, audited, minsup = int(step), True, ms
                break
        result = PartialResult(
            levels=levels, supports=supports, minsup=minsup,
            last_level=last_level, reason=reason, audited=audited,
            events=[e.as_dict() for e in self.events])
        self._finish_log(f"partial:{reason}")
        return result

    # ------------------------------------------------------------------
    def _record(self, attempt: int, kind: str, exc: BaseException,
                action: str, detail: str, backoff: float) -> None:
        ev = FaultEvent(
            attempt=attempt, kind=kind, error=repr(exc),
            level=getattr(exc, "level", None),
            action=action, detail=detail, backoff=backoff)
        self.events.append(ev)
        self._log_line(ev.as_dict())

    def _log_line(self, payload: dict) -> None:
        """Crash-safe structured log: one JSON line, flushed on write.
        The first line of a run truncates any stale file."""
        if not self.sup.fault_log_path:
            return
        mode = "a" if self._log_open else "w"
        self._log_open = True
        try:
            with open(self.sup.fault_log_path, mode) as f:
                f.write(json.dumps(payload) + "\n")
                f.flush()
        except OSError:
            pass                          # logging must never kill mining

    def _finish_log(self, outcome: str) -> None:
        self._log_line({"summary": {
            "outcome": outcome, "rung": self.rung,
            "n_events": len(self.events),
            "by_kind": dict(self.budget.by_kind) if self.budget else {},
            "watchdog_trips": len(self.watchdog.trips)
            if self.watchdog else 0}})


def _degrade(cfg: MirageConfig, rung: str) -> MirageConfig:
    """Config override for a degradation-ladder rung, by rung NAME.

    "single_sync" abandons the whole-run device loop for the per-level
    program (same kernels and shapes, one sync per level).  "pallas"
    keeps the current pipeline but drops the fused single-launch kernel
    for the two-launch backend ("pallas" on TPU, its "interpret" twin
    elsewhere).  "legacy" falls all the way back to the host-driven
    pipeline on the "ref" backend — the differential oracle, which
    dispatches no custom kernel at all.
    """
    import jax

    if rung == "as-configured":
        return cfg
    if rung == "single_sync":
        return dataclasses.replace(cfg, pipeline="single_sync")
    if rung == "pallas":
        on_tpu = jax.default_backend() == "tpu"
        pipeline = ("single_sync" if cfg.pipeline == "device_loop"
                    else cfg.pipeline)
        return dataclasses.replace(
            cfg, pipeline=pipeline,
            backend="pallas" if on_tpu else "interpret")
    return dataclasses.replace(cfg, pipeline="legacy", backend="ref",
                               packed_support=None)


def _default_mesh_factory(n_workers: int) -> MiningMesh:
    import jax

    devices = jax.devices()[:n_workers]
    return MiningMesh(jax_compat.make_mesh(
        (n_workers,), ("w",), devices=devices))
