"""Supervised recovery driver around :meth:`Mirage.mine` (DESIGN.md §10).

MIRAGE inherits MapReduce's contract: iterations are restartable because
level state hits durable storage between them, so the *job* survives
what kills a *task*.  This module is that job-level supervisor for the
JAX runtime.  It classifies every failure the mining loop can surface —
injected or real — and applies one of four recoveries:

  worker_loss  → elastically shrink the worker pool (largest divisor of
                 n_partitions below the current W, floored at
                 ``min_workers``) and resume from the latest intact
                 checkpoint; PR 2's canonical unsharded checkpoints make
                 the re-layout free.  When no smaller mesh exists the
                 level is simply replayed on the same mesh.
  kernel       → retry; after ``degrade_after`` kernel faults descend
                 the degradation ladder ``fused → pallas → legacy``
                 (rung 1 swaps the fused single-launch kernel for the
                 two-launch pallas/interpret backend; rung 2 abandons
                 the single-sync program for the legacy host-driven
                 pipeline, which dispatches no fused kernel at all).
                 A ``pipeline="device_loop"`` run gets one extra rung
                 FIRST: abandon the whole-run loop for the per-level
                 single-sync program, which re-syncs (and re-checks)
                 every level instead of once per run.
  transient    → (wire checksum failures and other flaky-link signals)
                 retry with exponential backoff, same configuration.
  state        → (checkpoint integrity) retry: the store has already
                 reaped the corrupt step, so the next attempt resumes
                 from the newest *intact* one — or restarts clean.

Anything unclassified is **fatal** and re-raised untouched: a
supervisor that swallows real bugs would poison every chaos guarantee.

Every decision is recorded as a structured :class:`FaultEvent`
(``events``; JSON-dumped to ``fault_log_path``), giving tests and the
CI chaos job an auditable recovery trace.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Optional, Sequence

from ..runtime import faults, jax_compat
from .graphdb import Graph
from .mapreduce import MiningMesh
from .mining import DistMiningResult, Mirage, MirageConfig

__all__ = ["SupervisorConfig", "FaultEvent", "MiningSupervisor",
           "classify", "elastic_shrink", "ladder_for"]

#: degradation-ladder rungs, most- to least-accelerated.  Each entry is
#: the config override applied at that rung; rung 0 is "as configured".
LADDER = ("as-configured", "pallas", "legacy")

#: the device-loop pipeline descends one extra rung first: give up the
#: whole-run while_loop for the per-level single-sync program (same
#: kernels, but a host sync — and a fresh chance — every level)
DEVICE_LOOP_LADDER = ("as-configured", "single_sync", "pallas", "legacy")


def ladder_for(cfg: MirageConfig) -> tuple[str, ...]:
    """The degradation ladder the ORIGINAL config starts from."""
    return (DEVICE_LOOP_LADDER if cfg.pipeline == "device_loop"
            else LADDER)


def classify(exc: BaseException) -> Optional[str]:
    """Map an exception to a recovery class, or None for fatal."""
    if isinstance(exc, faults.WorkerLost):
        return "worker_loss"
    if isinstance(exc, faults.KernelFault):
        return "kernel"
    if isinstance(exc, faults.WireIntegrityError):
        return "transient"
    if isinstance(exc, faults.CheckpointIntegrityError):
        return "state"
    return None


def elastic_shrink(workers: int, n_partitions: int,
                   min_workers: int = 1) -> Optional[int]:
    """Largest viable worker count below ``workers``: the partition
    count must stay divisible (blocked dim-0 sharding), so this is the
    largest divisor of ``n_partitions`` in [min_workers, workers)."""
    for w in range(workers - 1, min_workers - 1, -1):
        if n_partitions % w == 0:
            return w
    return None


@dataclasses.dataclass
class SupervisorConfig:
    max_retries: int = 5                # total recovery attempts
    backoff_base: float = 0.05          # seconds before attempt 2
    backoff_factor: float = 2.0
    backoff_max: float = 2.0
    degrade_after: int = 2              # kernel faults per ladder rung
    min_workers: int = 1                # elastic-shrink floor
    sleep_fn: Callable[[float], None] = time.sleep
    fault_log_path: Optional[str] = None


@dataclasses.dataclass
class FaultEvent:
    """One supervisor decision, structured for the fault log."""

    attempt: int
    kind: str                           # recovery class (or "fatal")
    error: str                          # repr of the triggering exception
    level: Optional[int]                # mining level, when known
    action: str                         # retry | shrink | degrade | give_up
    detail: str
    backoff: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class MiningSupervisor:
    """Run :meth:`Mirage.mine` to completion through faults.

    ``mesh`` seeds the worker pool (default single-device);
    ``mesh_factory(n_workers)`` builds the shrunken mesh on worker loss
    — the default takes the first n of ``jax.devices()``.  Recovery is
    only cheap with ``config.checkpoint_dir`` set (resume replays at
    most one level); without it every retry restarts from scratch,
    which is still correct, just slower.
    """

    def __init__(self, config: MirageConfig,
                 sup: Optional[SupervisorConfig] = None,
                 mesh: Optional[MiningMesh] = None,
                 mesh_factory: Optional[Callable[[int], MiningMesh]] = None):
        self.config = config
        self.sup = sup or SupervisorConfig()
        self.mesh = mesh or MiningMesh.single_device()
        self.mesh_factory = mesh_factory or _default_mesh_factory
        self.events: list[FaultEvent] = []
        self.rung = 0

    # ------------------------------------------------------------------
    def mine(self, graphs: Sequence[Graph], *,
             resume: bool = False) -> DistMiningResult:
        sup = self.sup
        cfg = self.config
        mesh = self.mesh
        ladder = ladder_for(cfg)
        attempt = 0
        kernel_faults = 0
        while True:
            try:
                result = Mirage(cfg, mesh).mine(
                    graphs, resume=resume or attempt > 0)
                self._flush_log()
                return result
            except Exception as exc:                      # noqa: BLE001
                kind = classify(exc)
                if kind is None:
                    self._record(attempt, "fatal", exc, "give_up",
                                 "unclassified failure — re-raised", 0.0)
                    self._flush_log()
                    raise
                attempt += 1
                if attempt > sup.max_retries:
                    self._record(attempt, kind, exc, "give_up",
                                 f"retry budget ({sup.max_retries}) "
                                 f"exhausted", 0.0)
                    self._flush_log()
                    raise
                backoff = min(
                    sup.backoff_base * sup.backoff_factor ** (attempt - 1),
                    sup.backoff_max)
                action, detail = "retry", "same configuration"

                if kind == "worker_loss":
                    w = elastic_shrink(mesh.n_workers, cfg.n_partitions,
                                       sup.min_workers)
                    if w is not None:
                        mesh = self.mesh_factory(w)
                        action = "shrink"
                        detail = (f"elastic shrink to {w} worker(s), "
                                  f"resume from checkpoint")
                    else:
                        detail = (f"no viable mesh below "
                                  f"{mesh.n_workers} worker(s) — replay "
                                  f"on the same mesh")
                elif kind == "kernel":
                    kernel_faults += 1
                    if (kernel_faults % sup.degrade_after == 0
                            and self.rung < len(ladder) - 1):
                        self.rung += 1
                        cfg = _degrade(cfg, ladder[self.rung])
                        action = "degrade"
                        detail = (f"descend ladder to rung {self.rung} "
                                  f"({ladder[self.rung]})")
                elif kind == "state":
                    detail = ("corrupt checkpoint reaped — resume from "
                              "newest intact step (or restart clean)")

                self._record(attempt, kind, exc, action, detail, backoff)
                if backoff > 0:
                    sup.sleep_fn(backoff)

    # ------------------------------------------------------------------
    def _record(self, attempt: int, kind: str, exc: BaseException,
                action: str, detail: str, backoff: float) -> None:
        self.events.append(FaultEvent(
            attempt=attempt, kind=kind, error=repr(exc),
            level=getattr(exc, "level", None),
            action=action, detail=detail, backoff=backoff))

    def _flush_log(self) -> None:
        if self.sup.fault_log_path:
            with open(self.sup.fault_log_path, "w") as f:
                json.dump({"rung": self.rung,
                           "events": [e.as_dict() for e in self.events]},
                          f, indent=2)


def _degrade(cfg: MirageConfig, rung: str) -> MirageConfig:
    """Config override for a degradation-ladder rung, by rung NAME.

    "single_sync" abandons the whole-run device loop for the per-level
    program (same kernels and shapes, one sync per level).  "pallas"
    keeps the current pipeline but drops the fused single-launch kernel
    for the two-launch backend ("pallas" on TPU, its "interpret" twin
    elsewhere).  "legacy" falls all the way back to the host-driven
    pipeline on the "ref" backend — the differential oracle, which
    dispatches no custom kernel at all.
    """
    import jax

    if rung == "as-configured":
        return cfg
    if rung == "single_sync":
        return dataclasses.replace(cfg, pipeline="single_sync")
    if rung == "pallas":
        on_tpu = jax.default_backend() == "tpu"
        pipeline = ("single_sync" if cfg.pipeline == "device_loop"
                    else cfg.pipeline)
        return dataclasses.replace(
            cfg, pipeline=pipeline,
            backend="pallas" if on_tpu else "interpret")
    return dataclasses.replace(cfg, pipeline="legacy", backend="ref",
                               packed_support=None)


def _default_mesh_factory(n_workers: int) -> MiningMesh:
    import jax

    devices = jax.devices()[:n_workers]
    return MiningMesh(jax_compat.make_mesh(
        (n_workers,), ("w",), devices=devices))
