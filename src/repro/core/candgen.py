"""Rightmost-path candidate generation (paper §IV-A.1).

Iteration k turns each frequent size-k pattern into size-(k+1) candidates
by adjoining one frequent edge:

  * **forward edge** — from any vertex on the rightmost path (RMP) to a
    brand-new vertex, which receives the next DFS id;
  * **back edge** — from the rightmost vertex (RMV) to another RMP vertex,
    provided the edge does not already exist (no multigraphs — paper
    Fig. 4 discussion).

The adjoined edge's label triple must belong to the globally frequent
edge alphabet (``F_1``), the Apriori prune.  Every candidate then passes
the min-dfs-code canonicality test (`dfscode.is_canonical`): of all
generation paths of a pattern exactly one survives, so the candidate
space is duplicate-free (completeness + no recount).

Candidates are *metadata* (host-side, tiny).  Each carries the join recipe
(`Extension`) the device layer executes against partition-local occurrence
lists.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .dfscode import Code, Edge5, code_to_graph, is_canonical, rightmost_path

__all__ = ["Extension", "Candidate", "EdgeAlphabet", "generate_candidates"]


@dataclasses.dataclass(frozen=True)
class Extension:
    """Join recipe for the device layer.

    forward:  child_emb = parent_emb + [v]  for edge occurrences (u, v) of
              ``triple`` with u == parent_emb[stub] and v not in parent_emb
    backward: child_emb = parent_emb        if an occurrence (u, v) of
              ``triple`` has u == parent_emb[stub] and v == parent_emb[to]
    """

    forward: bool
    stub: int            # dfs id of the existing attachment vertex
    to: int              # dfs id of other endpoint (new id if forward)
    triple: tuple[int, int, int]  # (l_stub, l_edge, l_other)


@dataclasses.dataclass(frozen=True)
class Candidate:
    code: Code           # parent code + one edge (already canonical)
    parent: int          # index into F_k
    ext: Extension

    @property
    def size(self) -> int:
        return len(self.code)


class EdgeAlphabet:
    """Globally frequent single-edge label triples (= F_1 keys).

    Stored symmetrically: ``(a, e, b)`` present iff ``(b, e, a)`` present.
    The *canonical* triple has ``a <= b``.
    """

    def __init__(self, triples: Iterable[tuple[int, int, int]]):
        s = set()
        for (a, e, b) in triples:
            s.add((int(a), int(e), int(b)))
            s.add((int(b), int(e), int(a)))
        self._set = frozenset(s)
        self.vlabels = sorted({a for (a, _, _) in s})
        self.elabels = sorted({e for (_, e, _) in s})

    def __contains__(self, triple: tuple[int, int, int]) -> bool:
        return tuple(int(x) for x in triple) in self._set

    def __len__(self) -> int:
        return len(self._set)

    def canonical(self) -> list[tuple[int, int, int]]:
        return sorted(t for t in self._set if t[0] <= t[2])

    def partners(self, label: int) -> list[tuple[int, int]]:
        """All (edge_label, other_vertex_label) adjoinable to ``label``."""
        return sorted({(e, b) for (a, e, b) in self._set if a == label})


def generate_candidates(
    frequent: Sequence[Code],
    alphabet: EdgeAlphabet,
) -> list[Candidate]:
    """All canonical size-(k+1) candidates from the frequent size-k set.

    Host-side cost is O(|F_k| · RMP · alphabet) plus one canonicality check
    per raw candidate — pattern-metadata scale, negligible next to
    support counting (the device side).
    """
    out: list[Candidate] = []
    for pidx, code in enumerate(frequent):
        g = code_to_graph(code)
        rmp = rightmost_path(code)
        rmv = rmp[-1]
        existing = {(min(int(u), int(v)), max(int(u), int(v)))
                    for (u, v) in g.edges}
        vl = g.vlabels
        n_v = g.n_vertices

        # ---- back edges: RMV -> strict-ancestor RMP vertex
        for w in rmp[:-1]:
            if (min(rmv, w), max(rmv, w)) in existing:
                continue  # would duplicate an edge (multigraph) — skip
            for (e_lab, other) in alphabet.partners(int(vl[rmv])):
                if other != int(vl[w]):
                    continue
                edge: Edge5 = (rmv, w, int(vl[rmv]), e_lab, int(vl[w]))
                child = code + (edge,)
                if is_canonical(child):
                    out.append(Candidate(child, pidx,
                                         Extension(False, rmv, w,
                                                   (int(vl[rmv]), e_lab, int(vl[w])))))

        # ---- forward edges: any RMP vertex -> new vertex (id = n_v)
        for w in rmp:
            for (e_lab, other) in alphabet.partners(int(vl[w])):
                edge = (int(w), n_v, int(vl[w]), e_lab, other)
                child = code + (edge,)
                if is_canonical(child):
                    out.append(Candidate(child, pidx,
                                         Extension(True, int(w), n_v,
                                                   (int(vl[w]), e_lab, other))))
    return out
