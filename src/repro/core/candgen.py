"""Rightmost-path candidate generation (paper §IV-A.1).

Iteration k turns each frequent size-k pattern into size-(k+1) candidates
by adjoining one frequent edge:

  * **forward edge** — from any vertex on the rightmost path (RMP) to a
    brand-new vertex, which receives the next DFS id;
  * **back edge** — from the rightmost vertex (RMV) to another RMP vertex,
    provided the edge does not already exist (no multigraphs — paper
    Fig. 4 discussion).

The adjoined edge's label triple must belong to the globally frequent
edge alphabet (``F_1``), the Apriori prune.  Every candidate then passes
the min-dfs-code canonicality test (`dfscode.is_canonical`): of all
generation paths of a pattern exactly one survives, so the candidate
space is duplicate-free (completeness + no recount).

Candidates are *metadata* (host-side, tiny).  Each carries the join recipe
(`Extension`) the device layer executes against partition-local occurrence
lists.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .dfscode import (Code, Edge5, code_to_graph, is_canonical,
                      rightmost_path, code_array_rightmost_path,
                      code_array_vertex_labels, min_dfs_canonical_array)

__all__ = ["Extension", "Candidate", "EdgeAlphabet", "generate_candidates",
           "filter_speculative", "CandidateSchedule", "schedule_candidates",
           "pad_schedule", "device_candidates", "device_schedule",
           "device_candgen_jit", "candidates_from_arrays"]


@dataclasses.dataclass(frozen=True)
class Extension:
    """Join recipe for the device layer.

    forward:  child_emb = parent_emb + [v]  for edge occurrences (u, v) of
              ``triple`` with u == parent_emb[stub] and v not in parent_emb
    backward: child_emb = parent_emb        if an occurrence (u, v) of
              ``triple`` has u == parent_emb[stub] and v == parent_emb[to]
    """

    forward: bool
    stub: int            # dfs id of the existing attachment vertex
    to: int              # dfs id of other endpoint (new id if forward)
    triple: tuple[int, int, int]  # (l_stub, l_edge, l_other)


@dataclasses.dataclass(frozen=True)
class Candidate:
    code: Code           # parent code + one edge (already canonical)
    parent: int          # index into F_k
    ext: Extension

    @property
    def size(self) -> int:
        return len(self.code)


class EdgeAlphabet:
    """Globally frequent single-edge label triples (= F_1 keys).

    Stored symmetrically: ``(a, e, b)`` present iff ``(b, e, a)`` present.
    The *canonical* triple has ``a <= b``.
    """

    def __init__(self, triples: Iterable[tuple[int, int, int]]):
        s = set()
        for (a, e, b) in triples:
            s.add((int(a), int(e), int(b)))
            s.add((int(b), int(e), int(a)))
        self._set = frozenset(s)
        self.vlabels = sorted({a for (a, _, _) in s})
        self.elabels = sorted({e for (_, e, _) in s})

    def __contains__(self, triple: tuple[int, int, int]) -> bool:
        return tuple(int(x) for x in triple) in self._set

    def __len__(self) -> int:
        return len(self._set)

    def canonical(self) -> list[tuple[int, int, int]]:
        return sorted(t for t in self._set if t[0] <= t[2])

    def partners(self, label: int) -> list[tuple[int, int]]:
        """All (edge_label, other_vertex_label) adjoinable to ``label``."""
        return sorted({(e, b) for (a, e, b) in self._set if a == label})


def generate_candidates(
    frequent: Sequence[Code],
    alphabet: EdgeAlphabet,
) -> list[Candidate]:
    """All canonical size-(k+1) candidates from the frequent size-k set.

    Host-side cost is O(|F_k| · RMP · alphabet) plus one canonicality check
    per raw candidate — pattern-metadata scale, negligible next to
    support counting (the device side).
    """
    out: list[Candidate] = []
    for pidx, code in enumerate(frequent):
        g = code_to_graph(code)
        rmp = rightmost_path(code)
        rmv = rmp[-1]
        existing = {(min(int(u), int(v)), max(int(u), int(v)))
                    for (u, v) in g.edges}
        vl = g.vlabels
        n_v = g.n_vertices

        # ---- back edges: RMV -> strict-ancestor RMP vertex
        for w in rmp[:-1]:
            if (min(rmv, w), max(rmv, w)) in existing:
                continue  # would duplicate an edge (multigraph) — skip
            for (e_lab, other) in alphabet.partners(int(vl[rmv])):
                if other != int(vl[w]):
                    continue
                edge: Edge5 = (rmv, w, int(vl[rmv]), e_lab, int(vl[w]))
                child = code + (edge,)
                if is_canonical(child):
                    out.append(Candidate(child, pidx,
                                         Extension(False, rmv, w,
                                                   (int(vl[rmv]), e_lab, int(vl[w])))))

        # ---- forward edges: any RMP vertex -> new vertex (id = n_v)
        for w in rmp:
            for (e_lab, other) in alphabet.partners(int(vl[w])):
                edge = (int(w), n_v, int(vl[w]), e_lab, other)
                child = code + (edge,)
                if is_canonical(child):
                    out.append(Candidate(child, pidx,
                                         Extension(True, int(w), n_v,
                                                   (int(vl[w]), e_lab, other))))
    return out


def filter_speculative(spec: Sequence[Candidate],
                       keep: Sequence[int]) -> list[Candidate]:
    """Narrow a speculatively generated candidate list to the surviving
    parents (the overlapped-candgen path, DESIGN.md §11).

    ``spec`` was generated from level k's FULL candidate list — a
    superset of the frequent set F_k, available before the device
    program reports which candidates survived.  ``keep`` holds the
    surviving indices, ascending.  Because ``generate_candidates``
    visits parents in list order and each parent's extensions (RMP,
    existing-edge set, canonicality) depend on that parent's code alone,
    dropping non-survivors and remapping ``parent`` to its rank in
    ``keep`` yields EXACTLY ``generate_candidates([F[i] for i in keep],
    alphabet)`` — same candidates, same order.  The equivalence is
    pinned by a conformance test; the speculation itself is therefore
    semantically free, costing only wasted host work when survival is
    sparse."""
    rank = {int(p): r for r, p in enumerate(keep)}
    return [dataclasses.replace(c, parent=rank[c.parent])
            for c in spec if c.parent in rank]


# ---------------------------------------------------------------------------
# Parent-grouped candidate scheduling (fused map-phase feed)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CandidateSchedule:
    """Tile-aligned candidate order for the fused level kernel.

    Candidates sorted by ``(parent, triple)`` and padded per group so
    every ``tile_c``-row block shares one parent OL and one edge-OL —
    the kernel streams those HBM tiles once per *block* instead of once
    per candidate.  ``inv[i]`` is the scheduled row of canonical
    candidate ``i``; gathering scheduled outputs with ``inv`` restores
    canonical order (the permutation round-trip the miner relies on).
    """

    meta: np.ndarray     # (Cs, 6) int32 [parent, stub, to, fwd, triple, valid]
    tiles: np.ndarray    # (Cs/tile_c, 2) int32 [parent, triple] per block
    inv: np.ndarray      # (C,) int32 — scheduled row of canonical candidate i
    tile_c: int

    @property
    def n_tiles(self) -> int:
        return self.tiles.shape[0]


def _padded_size(group_sizes: np.ndarray, tc: int) -> int:
    return int((-(-group_sizes // tc) * tc).sum())


def schedule_candidates(meta: np.ndarray, tile_c: int = 8, *,
                        max_inflation: float = 1.5) -> CandidateSchedule:
    """Host-side pass: group ``(C, 5)`` candidate metadata into uniform
    ``(parent, triple)`` tiles of ``tile_c`` rows.

    Stable-sorts by parent (major) then triple (minor), chunks each group
    into ``tile_c`` blocks, and pads the last block of each group with
    ``valid=0`` rows carrying the group's own (parent, triple) so block
    descriptors stay uniform.

    The tile size ADAPTS to the grouping structure: padding inflates the
    scheduled row count by one partial tile per distinct (parent, triple)
    pair, and padded rows burn real kernel compute (they are masked, not
    skipped).  Starting from ``tile_c`` and halving, the largest tile
    size whose padded row count stays within ``max_inflation``·C is
    chosen — candidate sets with heavy sibling sharing (the common case:
    every parent emits one candidate per alphabet partner) get wide
    blocks and maximal HBM-tile reuse, while adversarially scattered sets
    degrade gracefully to ``tile_c=1`` (still single-launch, still no
    (C, G) intermediates) instead of 8×-ing the map-phase work.

    Shape bucketing pads the finished schedule via ``pad_schedule``
    (whole invalid tiles + a parked inverse-permutation tail) — see
    ``core/buckets.py`` and the bucketed path of ``run_level``.
    """
    meta = np.asarray(meta, np.int32).reshape(-1, 5)
    C = meta.shape[0]
    if tile_c < 1:
        raise ValueError(f"tile_c={tile_c} must be >= 1")
    if C == 0:                       # emit one fully-padded tile
        return CandidateSchedule(
            np.tile(np.asarray([0, 0, 0, 1, 0, 0], np.int32), (tile_c, 1)),
            np.zeros((1, 2), np.int32), np.empty(0, np.int32), tile_c)

    order = np.lexsort((meta[:, 4], meta[:, 0]))     # triple minor, parent major
    keys = meta[order][:, [0, 4]]
    boundaries = np.any(keys[1:] != keys[:-1], axis=1)
    group_sizes = np.diff(np.concatenate(
        [[0], np.flatnonzero(boundaries) + 1, [C]]))
    while tile_c > 1 and _padded_size(group_sizes, tile_c) > max_inflation * C:
        tile_c = tile_c // 2

    starts = np.cumsum(group_sizes) - group_sizes    # into `order`
    tiles_per_group = -(-group_sizes // tile_c)
    padded = tiles_per_group * tile_c
    offsets = np.cumsum(padded) - padded             # group start row in sched
    Cs = int(padded.sum())

    group_keys = keys[starts]                        # (n_groups, 2) [parent, triple]
    tiles = np.repeat(group_keys, tiles_per_group, axis=0)

    sched = np.empty((Cs, 6), np.int32)              # pad rows first …
    sched[:, [0, 4]] = np.repeat(group_keys, padded, axis=0)
    sched[:, [1, 2]] = 0
    sched[:, 3] = 1
    sched[:, 5] = 0
    # … then overwrite the leading rows of each group span with the real
    # candidates (padding sits only at group tails, so every tile_c block
    # stays within one group)
    pos = np.repeat(offsets, group_sizes) + (np.arange(C)
                                             - np.repeat(starts, group_sizes))
    sched[pos, :5] = meta[order]
    sched[pos, 5] = 1
    inv = np.empty(C, np.int32)
    inv[order] = pos
    return CandidateSchedule(sched, tiles.astype(np.int32), inv, tile_c)


def pad_schedule(sched: CandidateSchedule, *, rows_to: int | None = None,
                 inv_to: int | None = None) -> CandidateSchedule:
    """Bucket-pad an existing schedule (see ``schedule_candidates``):
    whole invalid tiles up to ``rows_to`` scheduled rows, and the
    inverse permutation out to ``inv_to`` padded candidates."""
    meta, tiles, inv = _pad_schedule(sched.meta, sched.tiles, sched.inv,
                                     sched.tile_c, rows_to, inv_to)
    return CandidateSchedule(meta, tiles, inv, sched.tile_c)


def _pad_schedule(sched: np.ndarray, tiles: np.ndarray, inv: np.ndarray,
                  tile_c: int, pad_rows_to: int | None,
                  pad_inv_to: int | None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bucket padding: whole invalid tiles on the row axis, parked
    pointers on the inverse permutation (see ``schedule_candidates``)."""
    Cs = sched.shape[0]
    target = Cs
    if pad_rows_to is not None:
        target = max(Cs, -(-pad_rows_to // tile_c) * tile_c)
    need_inv = pad_inv_to is not None and pad_inv_to > inv.shape[0]
    if need_inv and target == Cs and not (sched[:, 5] == 0).any():
        target += tile_c             # guarantee a row to park inv padding
    if target > Cs:
        pad_row = np.asarray([0, 0, 0, 1, 0, 0], np.int32)
        sched = np.concatenate([sched,
                                np.tile(pad_row, (target - Cs, 1))])
        tiles = np.concatenate(
            [tiles, np.zeros(((target - Cs) // tile_c, 2), np.int32)])
    if need_inv:
        # an invalid row always exists here (appended above if needed),
        # so padded candidates can never read a real candidate's support
        park = int(np.flatnonzero(sched[:, 5] == 0)[0])
        inv = np.concatenate(
            [inv, np.full(pad_inv_to - inv.shape[0], park, np.int32)])
    return sched, tiles, inv


# ---------------------------------------------------------------------------
# Device-side candidate generation + schedule (pipeline="device_loop",
# DESIGN.md §13) — `generate_candidates` and `schedule_candidates` recast
# as fixed-shape jnp programs so the level loop can stay on device.
# ---------------------------------------------------------------------------

def _compact_mask(mask, cap: int):
    """Prefix-sum compact a flat bool mask into ``cap`` index slots.

    Returns (idx (cap,) int32 — flat indices of the first ``cap`` set
    entries in order, 0-filled past ``n``; n; overflow)."""
    pos = jnp.cumsum(mask) - 1
    n = mask.sum()
    dest = jnp.where(mask, pos, cap)
    idx = jnp.zeros((cap,), jnp.int32).at[dest].set(
        jnp.arange(mask.shape[0], dtype=jnp.int32), mode="drop")
    return idx, n.astype(jnp.int32), n > cap


def _parent_slots(code, pvalid, triples, n_vertex_slots: int):
    """All structural extension slots of one parent code (pre-canonicality).

    Slot order matches `generate_candidates` exactly: back-edge slots
    (RMP ancestors root-first × alphabet rows) then forward slots (RMP
    vertices root-first × alphabet rows); the triples table is the sorted
    directed closure of the alphabet, so masking rows on the stub label
    leaves the same sorted ``partners`` subsequence the host iterates.

    Returns (ok (SLOTS,), edge (SLOTS, 5), meta (SLOTS, 4) [stub, to,
    fwd, triple]) with SLOTS = (2·NV − 1)·T.
    """
    NV = n_vertex_slots
    L = code.shape[0]
    T = triples.shape[0]
    valid_e = code[:, 0] >= 0
    ne = valid_e.sum()
    vl = code_array_vertex_labels(code, NV)
    rmp, rmp_len, n_v = code_array_rightmost_path(code, NV)
    rmv = n_v - 1
    umin = jnp.minimum(code[:, 0], code[:, 1])
    umax = jnp.maximum(code[:, 0], code[:, 1])

    ta, te, tb = triples[:, 0], triples[:, 1], triples[:, 2]
    l_rmv = vl[jnp.clip(rmv, 0, NV - 1)]

    # ---- back-edge slots: (w_pos, t) for w_pos in [0, NV-2]
    wb = rmp[:NV - 1]                                     # (NV-1,)
    lb = vl[jnp.clip(wb, 0, NV - 1)]
    edge_dup = (valid_e[None, :] & (umin[None, :] == wb[:, None])
                & (umax[None, :] == rmv)).any(axis=1)     # (NV-1,)
    okb = ((jnp.arange(NV - 1) < rmp_len - 1)[:, None]
           & pvalid & (ne < L)
           & (ta[None, :] == l_rmv) & (tb[None, :] == lb[:, None])
           & ~edge_dup[:, None])                          # (NV-1, T)
    bi = jnp.broadcast_to(rmv, (NV - 1, T))
    bj = jnp.broadcast_to(wb[:, None], (NV - 1, T))
    b_edge = jnp.stack([bi, bj,
                        jnp.broadcast_to(ta[None, :], (NV - 1, T)),
                        jnp.broadcast_to(te[None, :], (NV - 1, T)),
                        jnp.broadcast_to(tb[None, :], (NV - 1, T))], axis=-1)
    b_meta = jnp.stack([bi, bj, jnp.zeros((NV - 1, T), jnp.int32),
                        jnp.broadcast_to(jnp.arange(T)[None, :],
                                         (NV - 1, T))], axis=-1)

    # ---- forward slots: (w_pos, t) for w_pos in [0, NV-1]
    wf = rmp                                              # (NV,)
    lf = vl[jnp.clip(wf, 0, NV - 1)]
    okf = ((jnp.arange(NV) < rmp_len)[:, None]
           & pvalid & (ne < L) & (n_v < NV)
           & (ta[None, :] == lf[:, None]))                # (NV, T)
    fi = jnp.broadcast_to(wf[:, None], (NV, T))
    fj = jnp.broadcast_to(n_v, (NV, T))
    f_edge = jnp.stack([fi, fj,
                        jnp.broadcast_to(ta[None, :], (NV, T)),
                        jnp.broadcast_to(te[None, :], (NV, T)),
                        jnp.broadcast_to(tb[None, :], (NV, T))], axis=-1)
    f_meta = jnp.stack([fi, fj, jnp.ones((NV, T), jnp.int32),
                        jnp.broadcast_to(jnp.arange(T)[None, :],
                                         (NV, T))], axis=-1)

    ok = jnp.concatenate([okb.reshape(-1), okf.reshape(-1)])
    edge = jnp.concatenate([b_edge.reshape(-1, 5), f_edge.reshape(-1, 5)])
    meta = jnp.concatenate([b_meta.reshape(-1, 4), f_meta.reshape(-1, 4)])
    return ok, edge.astype(jnp.int32), meta.astype(jnp.int32)


def device_candidates(codes, n_par, triples, *, n_vertex_slots: int,
                      raw_budget: int, budget: int, max_states: int):
    """Device twin of `generate_candidates` over array-shaped codes.

    Two-stage compaction keeps the expensive canonicality machine off
    label-mismatched slots: structural slots are prefix-sum compacted
    into ``raw_budget`` rows first, `min_dfs_canonical_array` is vmapped
    only over those, and canonical survivors compact again into
    ``budget`` rows — parent-major and order-preserving, so row r is
    EXACTLY the r-th candidate the host generator would emit.

    Returns (meta (budget, 5) [parent, stub, to, fwd, triple] pad rows
    [0,0,0,1,0]; child_codes (budget, L, 5) -1-padded; n_cand; flags
    (3,) bool [raw overflow, canonical overflow, state overflow]).
    """
    SP, L = codes.shape[0], codes.shape[1]
    NV = n_vertex_slots
    pvalid = jnp.arange(SP) < n_par
    ok, edge, meta4 = jax.vmap(
        lambda c, pv: _parent_slots(c, pv, triples, NV))(codes, pvalid)
    SLOTS = ok.shape[1]

    raw_idx, n_raw, raw_ovf = _compact_mask(ok.reshape(-1), raw_budget)
    raw_real = jnp.arange(raw_budget) < n_raw
    p_r = raw_idx // SLOTS                                # (CBR,)
    pcode = codes[p_r]                                    # (CBR, L, 5)
    e_r = edge.reshape(-1, 5)[raw_idx]
    m_r = meta4.reshape(-1, 4)[raw_idx]
    ne_r = (pcode[:, :, 0] >= 0).sum(axis=1)
    rows = jnp.arange(L)
    child = jnp.where((rows[None, :, None] == ne_r[:, None, None]),
                      e_r[:, None, :], pcode)             # (CBR, L, 5)

    canon, st_ovf = jax.vmap(
        lambda c: min_dfs_canonical_array(
            c, n_vertex_slots=NV, max_states=max_states))(child)

    can_idx, n_cand, can_ovf = _compact_mask(canon & raw_real, budget)
    can_real = jnp.arange(budget) < n_cand
    meta = jnp.where(
        can_real[:, None],
        jnp.concatenate([p_r[can_idx, None], m_r[can_idx]], axis=1),
        jnp.asarray([0, 0, 0, 1, 0], jnp.int32)[None, :])
    out_codes = jnp.where(can_real[:, None, None], child[can_idx], -1)
    flags = jnp.stack([raw_ovf, can_ovf, (st_ovf & raw_real).any()])
    return meta, out_codes, n_cand, flags


@functools.lru_cache(maxsize=64)
def device_candgen_jit(L: int, n_vertex_slots: int, raw_budget: int,
                       budget: int, max_states: int):
    """Cached jitted `device_candidates` for the candgen="device"
    stepping stone (standalone, outside the whole-run loop)."""
    return jax.jit(functools.partial(
        device_candidates, n_vertex_slots=n_vertex_slots,
        raw_budget=raw_budget, budget=budget, max_states=max_states))


def candidates_from_arrays(meta: np.ndarray, child_codes: np.ndarray,
                           n_cand: int,
                           triples: Sequence[tuple[int, int, int]]
                           ) -> list[Candidate]:
    """Rebuild host `Candidate` objects from `device_candidates` output
    (same candidates, same order — pinned by tests/test_device_loop.py)."""
    from .dfscode import array_to_code  # local: avoid cycle at import time
    out = []
    for r in range(int(n_cand)):
        p, stub, to, fwd, tri = (int(x) for x in meta[r])
        a, e, b = triples[tri]
        out.append(Candidate(array_to_code(child_codes[r]), p,
                             Extension(bool(fwd), stub, to,
                                       (int(a), int(e), int(b)))))
    return out


def device_schedule(meta, n_cand, *, tile_c: int, n_triples: int, rows: int):
    """Device twin of `schedule_candidates` under fixed shapes.

    Stable-sorts candidate slots by (parent, triple), sizes each group's
    tile-aligned span with a prefix sum, and emits the same
    (sched_meta, tiles, inv) triple the fused kernel consumes — all jnp,
    so it runs inside the while_loop body.  ``rows``/``tile_c`` are
    static; if the tile-padded row count exceeds ``rows`` the overflow
    flag is set (the driver bails to the host pipeline).  Padding slots
    of ``inv`` park at row 0 — downstream gathers mask on c_real.
    """
    CB = meta.shape[0]
    tc = tile_c
    NT = rows // tc
    BIG = jnp.int32(1 << 30)
    valid = jnp.arange(CB) < n_cand
    key = meta[:, 0] * n_triples + meta[:, 4]
    skey_in = jnp.where(valid, key, BIG)
    order = jnp.argsort(skey_in)                     # stable
    skey = skey_in[order]
    svalid = valid[order]

    first = svalid & ((jnp.arange(CB) == 0) | (skey != jnp.roll(skey, 1)))
    gid = jnp.cumsum(first) - 1                      # group id per sorted row
    n_groups = first.sum()
    gs = jnp.zeros((CB,), jnp.int32).at[
        jnp.where(svalid, gid, CB)].add(1, mode="drop")
    tpg = -(-gs // tc)                               # tiles per group
    padded = tpg * tc
    goff = jnp.cumsum(padded) - padded               # group start sched row
    gstart = jnp.cumsum(gs) - gs                     # group start sorted row
    cg = jnp.clip(gid, 0, CB - 1)
    srows = goff[cg] + (jnp.arange(CB) - gstart[cg])
    ovf = padded.sum() > rows

    inv = jnp.zeros((CB,), jnp.int32).at[order].set(
        jnp.where(svalid, jnp.clip(srows, 0, rows - 1), 0))

    gkeys = jnp.zeros((CB,), jnp.int32).at[
        jnp.where(first, gid, CB)].set(skey, mode="drop")
    tend = jnp.cumsum(tpg)
    tgid = jnp.searchsorted(tend, jnp.arange(NT), side="right")
    tkey = jnp.where(tgid < n_groups, gkeys[jnp.clip(tgid, 0, CB - 1)], 0)
    tiles = jnp.stack([tkey // n_triples, tkey % n_triples], axis=1)

    rkey = tkey[jnp.arange(rows) // tc]              # (rows,)
    zero = jnp.zeros((rows,), jnp.int32)
    sched = jnp.stack([rkey // n_triples, zero, zero, zero + 1,
                       rkey % n_triples, zero], axis=1)
    vals = jnp.concatenate(
        [meta[order], jnp.ones((CB, 1), jnp.int32)], axis=1)
    dest = jnp.where(svalid & (srows < rows), srows, rows)
    sched = sched.at[dest].set(vals, mode="drop")
    return sched, tiles.astype(jnp.int32), inv, ovf
