"""Continuous mining-invariant auditor (DESIGN.md §14).

Partial results are only trustworthy if the levels behind them are.
MIRAGE's level-synchronous loop makes the invariants that certify a
level cheap to state — and DIMSpan-style dataflow mining (arXiv
1703.01910) leans on exactly such pruning invariants for correctness —
so this module checks them *continuously*:

**On device** (``level_step``): each level program folds a bit-flag
*audit word* into the checksummed wire — support monotonicity against
the parent supports (anti-monotone pruning's load-bearing fact),
compaction integrity (every valid compact slot holds a true survivor,
which subsumes "survivor supports >= minsup"), support range against
the DB graph count, and the survivor count bound.  Zero word = the
level certified itself.

**On host** (this module): :class:`Auditor` spot-checks what the device
cannot see — downward closure (a sampled survivor's rightmost-removed
(k-1)-prefix must be the recorded frequent parent) and DFS-code
canonicality via ``dfscode.min_dfs_canonical_array`` — plus redundant
host-side re-checks of the wire's verdict consistency.  Violations
raise :class:`~repro.runtime.faults.AuditError`, a *state*-class fault
the supervisor heals by checkpoint replay.

:func:`audit_frequent_set` re-verifies a whole frequent set (levels +
supports) — the final gate a checkpoint passes before the supervisor
cuts a :class:`~repro.core.mining.PartialResult` at it.

:func:`audit_overhead_model` is the deterministic cost proxy the CI
gate (``benchmarks/check_recovery.py``) holds under 5% of the modeled
per-level critical path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import numpy as np

from ..runtime.faults import AuditError
from . import dfscode

__all__ = ["Auditor", "audit_frequent_set", "audit_overhead_model",
           "describe_audit_word"]

_FLAG_NAMES = {1: "monotonicity", 2: "compaction", 4: "support-range",
               8: "survivor-count"}

# state budget for the array canonicality machine; overflow falls back
# to the exact host checker
_CANON_MAX_STATES = 64


def describe_audit_word(word: int) -> str:
    names = [n for b, n in _FLAG_NAMES.items() if word & b]
    return "+".join(names) if names else "clean"


@functools.lru_cache(maxsize=32)
def _canon_fn(max_edges: int, n_vertex_slots: int):
    import jax
    return jax.jit(functools.partial(
        dfscode.min_dfs_canonical_array, n_vertex_slots=n_vertex_slots,
        max_states=_CANON_MAX_STATES))


def _is_canonical(code, device: bool = False) -> Optional[bool]:
    """Spot-check one code's canonicality.

    ``device=False`` (the in-loop default) runs the exact host checker
    — zero device traffic, preserving the pipeline's one-sync-per-level
    contract.  ``device=True`` (the offline partial-result gate) runs
    the bounded ``min_dfs_canonical_array`` machine instead, cross-
    validating the device-side implementation; None = inconclusive
    (state overflow)."""
    L = len(code)
    if L < 2:
        return True
    if not device:
        return bool(dfscode.is_canonical(tuple(code)))
    if L >= 32:
        return None
    arr = dfscode.code_to_array(code, L)
    canonical, overflow = _canon_fn(L, L + 1)(arr)
    if bool(overflow):
        return None
    return bool(canonical)


@dataclasses.dataclass
class Auditor:
    """Per-run host auditor: cheap sampled checks each level, a report
    row per call, :class:`AuditError` on any violation."""

    minsup: int
    n_graphs: int = -1
    samples: int = 2
    seed: int = 0
    # True routes canonicality spot checks through the device array
    # machine (offline gates only — in-loop audits stay host-pure to
    # preserve the one-sync-per-level contract)
    device_canon: bool = False
    report: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    # -- per-level (single_sync / legacy drivers) ----------------------

    def check_wire(self, level: int, audit_word: int) -> None:
        """A nonzero device audit word is a violated invariant."""
        if audit_word:
            raise AuditError(
                level, f"device audit word {audit_word:#x} "
                       f"({describe_audit_word(audit_word)})")

    def check_level(self, level: int, *, cands: Sequence,
                    keep: np.ndarray, gsup: np.ndarray,
                    parents: Sequence, supports: dict) -> None:
        """Host spot checks over one completed level's survivors.

        ``cands``: the level's Candidate list (canonical order);
        ``keep``: survivor indices into it; ``gsup``: their (C,) global
        supports; ``parents``: level k-1's frequent codes;
        ``supports``: the global code->support map (parents included).
        """
        keep = np.asarray(keep)
        gsup = np.asarray(gsup)
        checked = {"verdict": 0, "closure": 0, "canonical": 0}
        # verdict consistency: every survivor >= minsup, host-side again
        # (the device word already certified its own copy — this guards
        # the decoded host values end to end)
        if keep.size:
            bad = np.flatnonzero(gsup[keep] < self.minsup)
            if bad.size:
                i = int(keep[bad[0]])
                raise AuditError(
                    level, f"survivor {i} support {int(gsup[i])} "
                           f"< minsup {self.minsup}")
            checked["verdict"] = int(keep.size)
        if self.n_graphs >= 0 and keep.size:
            hi = np.flatnonzero(gsup[keep] > self.n_graphs)
            if hi.size:
                i = int(keep[hi[0]])
                raise AuditError(
                    level, f"survivor {i} support {int(gsup[i])} exceeds "
                           f"the DB graph count {self.n_graphs}")
        # sampled downward-closure + monotonicity + canonicality
        if keep.size:
            n = min(self.samples, keep.size)
            picks = self._rng.choice(keep, size=n, replace=False)
            for i in picks:
                c = cands[int(i)]
                parent = parents[c.parent] if 0 <= c.parent < len(
                    parents) else None
                if parent is None or tuple(c.code[:-1]) != tuple(parent):
                    raise AuditError(
                        level, f"candidate {int(i)}: rightmost-removed "
                               f"prefix is not the recorded frequent "
                               f"parent (downward closure)")
                psup = supports.get(tuple(parent))
                if psup is not None and int(gsup[int(i)]) > int(psup):
                    raise AuditError(
                        level, f"candidate {int(i)}: support "
                               f"{int(gsup[int(i)])} > parent support "
                               f"{int(psup)} (monotonicity)")
                checked["closure"] += 1
                ok = _is_canonical(tuple(c.code), self.device_canon)
                if ok is False:
                    raise AuditError(
                        level, f"candidate {int(i)}: survivor DFS code "
                               f"is not canonical")
                if ok:
                    checked["canonical"] += 1
        self.report.append({"level": level, "checked": checked,
                            "n_survivors": int(keep.size), "ok": True})

    # -- whole-prefix (device_loop boundaries / checkpoint cuts) -------

    def check_levels(self, levels: Sequence[Sequence], supports: dict,
                     *, start_level: int = 2) -> None:
        """Audit decoded levels ``start_level..`` of a frequent-set
        prefix: supports in range, monotone against the rightmost-
        removed parent, parent present (downward closure), sampled
        canonicality."""
        for li in range(start_level - 1, len(levels)):
            lvl = levels[li]
            level_no = li + 1
            prev = {tuple(c) for c in levels[li - 1]} if li else set()
            n_canon = 0
            codes = list(lvl)
            n = min(self.samples, len(codes))
            picks = (self._rng.choice(len(codes), size=n, replace=False)
                     if codes else [])
            picks = set(int(p) for p in np.atleast_1d(picks)) if n else set()
            for ci, code in enumerate(codes):
                code = tuple(code)
                s = supports.get(code)
                if s is None or s < self.minsup:
                    raise AuditError(
                        level_no, f"frequent code missing a support >= "
                                  f"minsup (got {s})")
                if self.n_graphs >= 0 and s > self.n_graphs:
                    raise AuditError(
                        level_no, f"support {s} exceeds the DB graph "
                                  f"count {self.n_graphs}")
                if li >= 1 and len(code) > 1:
                    parent = tuple(code[:-1])
                    if parent not in prev:
                        raise AuditError(
                            level_no, "rightmost-removed parent absent "
                                      "from the previous level "
                                      "(downward closure)")
                    ps = supports.get(parent)
                    if ps is not None and s > ps:
                        raise AuditError(
                            level_no, f"support {s} > parent support "
                                      f"{ps} (monotonicity)")
                if ci in picks:
                    if _is_canonical(code, self.device_canon) is False:
                        raise AuditError(
                            level_no, "frequent DFS code is not "
                                      "canonical")
                    n_canon += 1
            self.report.append({"level": level_no, "n_codes": len(codes),
                                "checked": {"canonical": n_canon},
                                "ok": True})


def audit_frequent_set(levels: Sequence[Sequence], supports: dict,
                       minsup: Optional[int], *, n_graphs: int = -1,
                       samples: int = 2, seed: int = 0) -> list:
    """Re-verify a whole frequent set (e.g. a loaded checkpoint) before
    trusting it as a partial result.  Returns the audit report; raises
    :class:`AuditError` on any violation.  ``minsup=None`` skips the
    threshold check (pre-§14 checkpoints without recorded minsup)."""
    a = Auditor(minsup=0 if minsup is None else int(minsup),
                n_graphs=n_graphs, samples=samples, seed=seed,
                device_canon=True)
    a.check_levels(levels, supports, start_level=1 if minsup else 2)
    return a.report


def audit_overhead_model(cp: int, n_partitions: int, n_workers: int, *,
                         parents: Optional[int] = None,
                         reduce: str = "reduce_scatter",
                         sharded: Optional[bool] = None,
                         packed: bool = False,
                         samples: int = 2) -> dict:
    """Deterministic model of the audit's share of a level's critical
    path (bytes moved — the same proxy the scaling gate uses; CPU wall
    time is noisy, bytes are not).

    Audit costs per level: ONE extra int32 wire word per shard on the
    host transfer, a psummed pair of int32 violation counters in the
    collective phase (sharded only), the PARENT-indexed support upload
    (one int32 per parent slot — candidates gather it on device through
    the meta parent column, so the upload is O(parents) not O(cp);
    ``parents`` defaults to cp/4, the typical rightmost-extension
    fanout), and ``samples`` host spot checks (exact host canonicality
    on a <=L-edge code — off the device critical path entirely).

    The path those bytes are charged against is the level's full
    host<->device traffic: the modeled wire cost
    (``level_step.wire_cost_model``) PLUS the (cp, 5) int32 candidate
    meta upload that every level already ships to the device."""
    from .level_step import wire_cost_model
    base = wire_cost_model(cp, n_partitions, n_workers, reduce=reduce,
                           sharded=sharded, packed=packed)
    if sharded is None:
        sharded = reduce == "reduce_scatter"
    if parents is None:
        parents = max(1, cp // 4)
    shards = n_workers if sharded else 1
    audit_host = shards * 4                 # one audit word per shard
    audit_coll = (2 * 4 * (n_workers - 1) / n_workers) if sharded else 0.0
    audit_upload = parents * 4              # parent-indexed psup upload
    # uploads ride host->device ahead of dispatch; weight them like the
    # host wire (they share the PCIe/ICI link budget) — and so does the
    # candidate meta upload already on every level's path
    audit_bytes = audit_host + audit_coll + audit_upload
    path_bytes = base["total_bytes"] + cp * 5 * 4
    return {"audit_bytes": audit_bytes, "path_bytes": path_bytes,
            "overhead": audit_bytes / max(path_bytes, 1.0),
            "samples": samples, "parents": parents}
