"""Single-sync device-resident level program (DESIGN.md §8).

The PR-1 driver still crossed the host↔device boundary several times per
mining level: fetch the support vector, build a Python ``keep`` list,
re-upload the survivor metadata, loop the escalation valve from host
control flow, and detour through the host to compute the LPT straggler
repack from the embed-count signal.  Each crossing is a device sync — the
iterative-MapReduce overhead the paper identifies (§IV-B) surviving in
miniature as dispatch latency.

This module fuses the whole per-level dataflow into ONE jitted program:

  1. pass-1 support counting   (fused single-launch kernel, or the
                                vmapped ref/pallas backends, per device)
  2. dense-collective threshold (psum | reduce_scatter — the shuffle)
  3. survivor compaction        (verdict-masked prefix-sum rank, one
                                scatter; survivor metadata gathered to
                                the front, padded to a static cap S)
  4. pass-2 materialization     (child OLs for the S compact slots,
                                data-local per partition)
  5. straggler repack           (per-partition embed-cost → on-device
                                LPT permutation + trigger decision; the
                                permutation rides home in the wire and,
                                when it fired, ``permute_stores`` gathers
                                the OL + edge-OL stores into the new
                                layout in a separate cached device
                                program — no host detour, and the rare
                                all-to-all doesn't tax every level's
                                compile)

The host receives exactly ONE device→host transfer per level: the packed
int32 *wire*.  The wire comes in two layouts (DESIGN.md §11):

**Dense** (``psum``, or ``sharded_wire=False``) — one replicated vector:

  [0:Cp]      global support per (padded) candidate
  [Cp+0]      true survivor count (may exceed the cap S — driver retries)
  [Cp+1]      overflow (matches dropped by the M cap, survivors only)
  [Cp+2]      rebalanced flag (0/1)
  [Cp+3]      imbalance, 16.16 fixed point
  [Cp+4]      audit word — device-side invariant check bit flags
              (DESIGN.md §14; 0 = every check passed)
  [Cp+5:-1]   the (NP,) partition permutation that was applied
  [-1]        checksum word over everything before it (DESIGN.md §10)

**Sharded** (``reduce_scatter``; the default single-sync layout) — the
wire itself is sharded over the W workers.  The support vector is never
all-gathered on device: the ``psum_scatter`` output stays put and each
worker packs (and transfers to the host) only its own C/W key slice,
plus a replicated copy of the scalar words and permutation and its own
shard checksum:

  worker w's shard (length Cp/W + 5 + NP + 1):
    [0:Cp/W]  global support for keys [w·Cp/W, (w+1)·Cp/W)
    [...]     n_keep | overflow | rebalanced | imbalance | audit | perm
              | checksum

The host reassembles the canonical (Cp,) support vector by concatenating
the verified shards (blocked dim-0 sharding ⇒ device order is key
order) and reads the scalar words from shard 0.

**Packed** (DESIGN.md §12; orthogonal to dense/sharded, default for
single-sync): either layout's gsup slice ships two uint16 supports per
int32 word — the checksum covers the packed words, and
``reassemble_wire`` expands the slice back to int32 only after
verification, so ``unpack_wire`` sees an identical body.  Upstream of
the wire, ``packed`` also selects the bitset kernel (verdict bitsets in
VMEM, AND+popcount support counting) and bit-packed verdict lanes in
the reduce_scatter shuffle.  Per level this removes
the (W-1)/W·Cp·4B support all-gather from the collective phase (fig19's
~40% wire cut) AND shrinks each worker's device→host transfer from the
full wire to its 1/W slice — the per-iteration host traffic DIMSpan
(arXiv 1703.01910) identifies as the distributed-FSM killer.

From either layout the host derives everything else (frequent verdicts,
survivor ids, escalation and rebalance bookkeeping).  Checksums are
computed on device and re-computed host-side per shard before any field
is decoded: a corrupted transfer triggers a bounded re-fetch from the
(pristine) device buffer, then a ``WireIntegrityError`` — never
silently wrong supports.

``dispatch_level`` / :class:`PendingLevel` split the level into an
asynchronous dispatch and the blocking wire sync, so the driver can run
the next level's host candidate generation in the shadow of the
in-flight device program (the overlap state machine, DESIGN.md §11).

Exceptional paths — the escalation valve (overflow > 0) and a survivor-
cap miss (n_keep > S) — fall back to the cheap materialize-only program
from the *preserved* inputs (the wire's pass-1 supports stay valid); they
cost extra syncs only when they fire.  Because such a retry consumes the
parent OL store again, its buffers are donated only when no retry is
possible: escalation disabled or M already at its ceiling, and S
covering the full real candidate set (S >= C rules a cap miss out).

Shape bucketing (``core/buckets.py``, DESIGN.md §9): the program is
cached per STATIC config only — the true candidate count ``c_real``
rides in as a traced scalar, so consecutive levels whose bucketed
shapes (Cp, S, M, K, schedule rows) coincide reuse one compiled
program instead of paying a fresh XLA compile per level.  The driver
passes ``child_width`` (the bucketed child vertex-slot width; None
reproduces the exact K+1 growth) and ``sched_floor`` (the fused
schedule's row-bucket floor).  When bucketed shapes repeat, the donated
parent store has exactly the child store's shape and XLA aliases the
buffers — the donation arena — rather than merely freeing them at
program exit; ``permute_stores`` aliases unconditionally (its outputs
always match its inputs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..kernels.ops import (Backend, device_local_supports,
                           fused_level_supports,
                           fused_level_supports_packed, is_fused_backend)
from ..runtime import faults, jax_compat
from .embedding import LevelOL, materialize_one
from .mapreduce import MiningMesh, reduce_supports, worker_imbalance

__all__ = ["LevelWire", "LevelOutputs", "PendingLevel", "dispatch_level",
           "run_level", "unpack_wire", "reassemble_wire", "wire_words",
           "wire_cost_model", "lpt_permutation", "wire_checksum",
           "fetch_wire", "AUDIT_MONOTONIC", "AUDIT_COMPACT",
           "AUDIT_RANGE", "AUDIT_NKEEP"]

_IMBAL_FX = 1 << 16

# wire scalar words per shard: n_keep | overflow | rebalanced |
# imbalance | audit (DESIGN.md §14)
_N_SCALARS = 5

# audit-word bit flags (device-side invariant checks, 0 = clean)
AUDIT_MONOTONIC = 1     # child support exceeds its parent's support
AUDIT_COMPACT = 2       # a valid compact slot holds a non-survivor
AUDIT_RANGE = 4         # support negative or above the DB graph count
AUDIT_NKEEP = 8         # survivor count exceeds the real candidate count

# Fibonacci / murmur-style 32-bit odd mixing constants.  The checksum is
# a position-salted multiplicative sum: word i contributes
# (w_i ^ i*PHI32) * MIX, all in wrapping uint32, so both a flipped bit
# anywhere and two swapped words change the sum.  The final >> 1 makes
# the value fit int32 exactly, letting it ride the int32 wire itself.
_CSUM_SALT = 0x9E3779B1
_CSUM_MIX = 0x85EBCA77

_WIRE_FETCH_ATTEMPTS = 3


def wire_checksum(wire):
    """Checksum word for a packed int32 wire (all words but the last).

    Pure wrapping-uint32 arithmetic so the device (jnp, inside the level
    program) and the host (np, before decoding) compute bit-identical
    values."""
    xp = jnp if isinstance(wire, jax.Array) else np
    u = wire.astype(xp.uint32)
    idx = xp.arange(u.shape[0], dtype=xp.uint32)
    mixed = (u ^ (idx * xp.uint32(_CSUM_SALT))) * xp.uint32(_CSUM_MIX)
    return (mixed.sum(dtype=xp.uint32) >> xp.uint32(1)).astype(xp.int32)


def wire_words(cp: int, n_partitions: int, n_shards: int = 1,
               packed: bool = False) -> int:
    """Total int32 words of the packed wire: ``n_shards`` shards of
    [gsup slice | 5 scalars | perm | checksum].  ``n_shards=1`` is the
    dense layout.  With ``packed`` (DESIGN.md §12) each shard's gsup
    slice ships two uint16 supports per int32 word — ``ceil(cs/2)``
    words for a ``cs``-support slice."""
    if cp % n_shards:
        raise ValueError(f"Cp={cp} not divisible into {n_shards} shards")
    cs = cp // n_shards
    gw = -(-cs // 2) if packed else cs
    return n_shards * (gw + _N_SCALARS + n_partitions + 1)


def reassemble_wire(host: np.ndarray, n_partitions: int,
                    n_shards: int = 1, *, packed: bool = False,
                    cp: Optional[int] = None) -> Optional[np.ndarray]:
    """Verify a fetched wire's per-shard checksums and reassemble the
    dense body ``[gsup (Cp) | scalars | perm]`` (checksums stripped).

    Returns None when any shard fails its checksum — the caller
    re-fetches.  With ``n_shards=1`` this is exactly the dense-layout
    verify+strip.  Scalar words and the permutation are replicated
    device-side; shard 0's (checksum-verified) copy is authoritative.

    With ``packed`` each shard's gsup slice carries two uint16 supports
    per int32 word (``cp`` — the padded candidate total — is then
    required to locate the field boundaries).  The checksum is verified
    over the PACKED words exactly as the device computed it, and only
    then is the slice expanded back to int32 supports, so the returned
    body is layout-independent and ``unpack_wire`` never changes."""
    shards = host.reshape(n_shards, -1)
    for s in shards:
        if int(wire_checksum(s[:-1])) != int(s[-1]):
            return None
    if not packed:
        cs = shards.shape[1] - (_N_SCALARS + n_partitions + 1)
        return np.concatenate([shards[:, :cs].reshape(-1), shards[0, cs:-1]])
    if cp is None:
        raise ValueError("packed wire reassembly needs cp")
    cs = cp // n_shards                                # supports per shard
    gw = -(-cs // 2)                                   # packed words
    u = shards[:, :gw].astype(np.uint32)
    lo = (u & np.uint32(0xFFFF)).astype(np.int32)
    hi = (u >> np.uint32(16)).astype(np.int32)
    gsup = np.stack([lo, hi], axis=-1).reshape(n_shards, -1)[:, :cs]
    return np.concatenate([gsup.reshape(-1), shards[0, gw:-1]])


def wire_cost_model(cp: int, n_partitions: int, n_workers: int, *,
                    reduce: str, sharded: Optional[bool] = None,
                    packed: bool = False) -> dict:
    """Modeled per-worker wire bytes for one level (the deterministic
    proxy the scaling CI gate checks — CPU wall time is noisy, bytes
    are not).

    ``host_bytes``       device→host transfer this worker performs for
                         the level wire (int32 words × 4);
    ``collective_bytes`` inter-device bytes this worker moves in the
                         shuffle collectives (ring factors, as in
                         ``benchmarks/bench_reducers``).

    Layouts: ``psum`` — dense wire + 2(W-1)/W·Cp·4B all-reduce;
    dense ``reduce_scatter`` (``sharded=False``) — psum_scatter (4B) +
    verdict all-gather (1B) + support all-gather (4B), dense wire;
    sharded ``reduce_scatter`` (default) — the support all-gather
    disappears (each worker keeps its C/W slice; only the 1-byte
    verdicts and the tiny (NP,) cost vector are gathered) and the host
    transfer shrinks to the worker's own shard.

    ``packed`` (DESIGN.md §12) shrinks the reduce_scatter verdict
    all-gather to bit lanes (``ceil(cp/32)`` uint32 words instead of
    ``cp`` int8 lanes) and the wire's gsup slice to two uint16 supports
    per int32 word."""
    W = n_workers
    if sharded is None:
        sharded = reduce == "reduce_scatter"
    ring = (W - 1) / W
    tail = _N_SCALARS + n_partitions + 1          # scalars + perm + csum
    vbytes = (-(-cp // 32) * 4) if packed else cp * 1   # verdict gather

    def gw(n):                                    # gsup words on the wire
        return -(-n // 2) if packed else n

    if reduce == "psum":
        coll = 2 * ring * cp * 4
        host = (gw(cp) + tail) * 4
    elif not sharded:
        coll = ring * (cp * 4 + vbytes + cp * 4)
        host = (gw(cp) + tail) * 4
    else:
        coll = ring * (cp * 4 + vbytes + n_partitions * 4)
        host = (gw(cp // W) + tail) * 4
    return {"host_bytes": host, "collective_bytes": coll,
            "total_bytes": host + coll}


@dataclasses.dataclass
class LevelWire:
    """Host view of the single per-level transfer."""

    gsup: np.ndarray        # (C,) int32 — global supports, canonical order
    n_keep: int             # true survivor count (may exceed the cap)
    overflow: int           # matches dropped by the M cap (survivors only)
    rebalanced: bool
    imbalance: float
    perm: np.ndarray        # (NP,) applied partition permutation
    audit: int = 0          # device audit bit flags (0 = clean, §14)


@dataclasses.dataclass
class LevelOutputs:
    """Device-resident results of one level program invocation.  The
    edge store passes through untouched; when the wire reports a
    rebalance the driver repacks everything via ``permute_stores``."""

    wire: LevelWire
    pol: jnp.ndarray        # (NP, S, G, M, K+1) — compact survivor OLs
    pmask: jnp.ndarray      # (NP, S, G, M)
    src: jnp.ndarray        # (NP, T, G, F) — edge store (as passed in)
    dst: jnp.ndarray
    emask: jnp.ndarray


def lpt_permutation(cost: jnp.ndarray, n_workers: int) -> jnp.ndarray:
    """Device LPT repack: heaviest partition first onto the lightest
    worker bucket with room; emits the permutation laying buckets
    contiguously (matching the blocked dim-0 sharding).  The device twin
    of ``mining._lpt_order`` — NP is tiny, so the sequential fori_loop
    is noise next to the level compute it rides along with."""
    npn = cost.shape[0]
    per = npn // n_workers
    order = jnp.argsort(-cost)

    def body(i, state):
        load, cnt, pos = state
        item = order[i]
        bucket_key = jnp.where(cnt < per, load, jnp.inf)
        b = jnp.argmin(bucket_key)
        pos = pos.at[b * per + cnt[b]].set(item.astype(jnp.int32))
        load = load.at[b].add(cost[item])
        cnt = cnt.at[b].add(1)
        return load, cnt, pos

    _, _, pos = jax.lax.fori_loop(
        0, npn, body,
        (jnp.zeros((n_workers,), cost.dtype),
         jnp.zeros((n_workers,), jnp.int32),
         jnp.zeros((npn,), jnp.int32)))
    return pos


@functools.lru_cache(maxsize=256)
def _level_program(mmesh: MiningMesh, minsup: int,
                   backend: Backend, reduce: str, max_embeddings: int,
                   survivor_cap: int, rebalance: bool, threshold: float,
                   donate: bool, child_width: Optional[int],
                   sharded: bool, packed: bool = False,
                   n_graphs: int = -1):
    """Build (and cache per static config) the jitted level program.

    The true candidate count is a TRACED argument (``c_real``), not part
    of the cache key: only bucketed quantities (shapes, the survivor
    cap, M, the child vertex width) select a program, so levels with
    coinciding buckets share one compile (DESIGN.md §9).

    With ``sharded`` the wire is packed per device INSIDE the shard_map
    (each worker's shard carries its C/W support slice; DESIGN.md §11),
    which requires the ``reduce_scatter`` shuffle — the support vector
    is then never all-gathered on device.  The rebalance decision moves
    inside too, fed by an all-gather of the tiny (NP,) cost vector.

    With ``packed`` (DESIGN.md §12) the boolean-per-graph support signal
    travels bit-packed end to end: the fused kernel accumulates verdict
    bitsets in VMEM (AND+popcount support counting), the reduce_scatter
    verdict gather ships uint32 bit lanes, and the wire's gsup slice
    carries two uint16 supports per int32 word (the driver guarantees
    supports < 2^16 by gating on the DB's graph count).  Every output is
    bit-identical to the dense program."""
    axes = mmesh.axes
    W = mmesh.n_workers
    parts = mmesh.spec_parts()
    rep = mmesh.replicated()
    fused = is_fused_backend(backend)
    interpret = backend.endswith("interpret")
    S = survivor_cap
    with_rebalance = rebalance and W > 1
    if sharded and reduce != "reduce_scatter":
        raise ValueError(
            f"the sharded wire needs reduce='reduce_scatter' (each worker "
            f"owns a support slice), got reduce={reduce!r}")

    def _pack_wire(gsup, n_keep, overflow, do_reb, imbal, audit, perm):
        gsup = gsup.astype(jnp.int32)
        if packed:
            # two uint16 supports per int32 word (lossless: the driver
            # only enables packing when every support fits 16 bits);
            # the checksum below covers the PACKED words — the host
            # verifies before expanding (reassemble_wire).
            u = gsup.astype(jnp.uint32)
            if u.shape[0] % 2:
                u = jnp.concatenate([u, jnp.zeros((1,), jnp.uint32)])
            w = u[0::2] | (u[1::2] << jnp.uint32(16))
            gsup = jax.lax.bitcast_convert_type(w, jnp.int32)
        body = jnp.concatenate([
            gsup,
            jnp.stack([n_keep, overflow, do_reb.astype(jnp.int32),
                       (imbal * _IMBAL_FX).astype(jnp.int32),
                       audit.astype(jnp.int32)]),
            perm,
        ])
        return jnp.concatenate([body, wire_checksum(body)[None]])

    def _rebalance(cost):
        NP = cost.shape[0]
        imbal = worker_imbalance(cost, W)
        if with_rebalance:
            do_reb = imbal > threshold
            perm = jnp.where(
                do_reb, lpt_permutation(cost.astype(jnp.float32), W),
                jnp.arange(NP, dtype=jnp.int32))
        else:
            do_reb = jnp.zeros((), bool)
            perm = jnp.arange(NP, dtype=jnp.int32)
        return do_reb, imbal, perm

    def core(c_real, psup, *args):
        if fused:
            sched_meta, tiles, inv, pol, pmask, src, dst, emask = args
            if packed:
                # verdict accumulator = ceil(G/32) uint32 words in VMEM;
                # local support counting is AND+popcount per tile_c block
                sup_pp, emb_s, _vbits = fused_level_supports_packed(
                    sched_meta, tiles, pol, pmask, src, dst, emask,
                    interpret=interpret)
            else:
                sup_pp, emb_s = fused_level_supports(
                    sched_meta, tiles, pol, pmask, src, dst, emask,
                    interpret=interpret)
            local_sup = jnp.take(sup_pp.sum(0), inv)        # (Cp,) canonical
            emb_pp = jnp.take(emb_s, inv, axis=1)           # (PP, Cp)
            meta_can = jnp.take(sched_meta[:, :5], inv, axis=0)
        else:
            meta, pol, pmask, src, dst, emask = args
            local_sup, _, emb_pp = device_local_supports(
                meta, pol, pmask, src, dst, emask, backend=backend,
                packed=packed)
            meta_can = meta

        # sharded: gsup stays the psum_scatter output — this worker's
        # (Cp/W,) key slice, never all-gathered; only the 1-byte
        # verdicts travel the ring (the fig19 wire cut made total) —
        # bit lanes instead when packed.
        gsup, verdict = reduce_supports(local_sup, axes, minsup, reduce,
                                        gather_gsup=not sharded,
                                        packed=packed)
        Cp = verdict.shape[0]
        real = jnp.arange(Cp) < c_real
        keep = (verdict != 0) & real

        # verdict-masked prefix-sum compaction: survivor i's compact slot
        # is its rank among survivors; one scatter inverts rank -> id.
        # Ranks past the cap S (and non-survivors) scatter out of bounds.
        rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
        n_keep = rank[-1] + 1
        dest = jnp.where(keep, rank, S)
        surv = (jnp.zeros((S,), jnp.int32)
                .at[dest].set(jnp.arange(Cp, dtype=jnp.int32), mode="drop"))
        cmeta = jnp.take(meta_can, surv, axis=0)            # (S, 5)
        valid_s = jnp.arange(S) < n_keep                    # (S,)

        # continuous invariant audit (DESIGN.md §14): bit flags over the
        # level's own outputs, folded into the checksummed wire.  psup
        # is PARENT-indexed (one int32 per parent-store slot, -1 =
        # unknown / padding); each candidate gathers its parent's
        # support through the replicated meta parent column, so the
        # upload is O(parents), not O(candidates).  In sharded mode
        # gsup is this worker's key slice, so the slice-local violation
        # counts are psummed; the compaction and survivor-count checks
        # run on replicated values.
        par = meta_can[:, 0]
        psc = jnp.where(
            (par >= 0) & (par < psup.shape[0]),
            jnp.take(psup, jnp.clip(par, 0, psup.shape[0] - 1)), -1)
        if sharded:
            w_idx = jax.lax.axis_index(axes)
            cs_a = gsup.shape[0]
            psl = jax.lax.dynamic_slice(psc, (w_idx * cs_a,), (cs_a,))
            real_a = (w_idx * cs_a + jnp.arange(cs_a)) < c_real
        else:
            psl, real_a = psc, real
        gs_a = gsup.astype(jnp.int32)
        mono_bad = ((gs_a > psl) & real_a & (psl >= 0)).sum()
        rng_bad = (((gs_a < 0) | (gs_a > n_graphs)) & real_a).sum() \
            if n_graphs >= 0 else jnp.zeros((), jnp.int32)
        if sharded:
            mono_bad = jax.lax.psum(mono_bad, axes)
            rng_bad = jax.lax.psum(rng_bad, axes)
        comp_bad = (valid_s & ~jnp.take(keep, surv)).sum()
        audit = (jnp.where(mono_bad > 0, AUDIT_MONOTONIC, 0)
                 | jnp.where(comp_bad > 0, AUDIT_COMPACT, 0)
                 | jnp.where(rng_bad > 0, AUDIT_RANGE, 0)
                 | jnp.where(n_keep > c_real, AUDIT_NKEEP, 0)
                 ).astype(jnp.int32)

        # pass 2, cond-gated per compact slot: lax.map is a scan, so the
        # skip branch of invalid (cap-padding) slots really executes a
        # constant fill — unlike a vmapped select, padding costs ~nothing
        PP, _, G, _, K = pol.shape
        Mc = max_embeddings
        Wk = child_width if child_width is not None else K + 1

        def per_slot(slot):
            cand, valid = slot

            def do(_):
                ch, mk, over = jax.vmap(
                    lambda po, pm, s, d, e: materialize_one(
                        LevelOL(po, pm), s, d, e, cand,
                        max_embeddings=Mc, out_width=Wk)
                )(pol, pmask, src, dst, emask)
                return ch, mk, over.sum()

            def skip(_):
                return (jnp.full((PP, G, Mc, Wk), -1, jnp.int32),
                        jnp.zeros((PP, G, Mc), bool),
                        jnp.zeros((), jnp.int32))

            return jax.lax.cond(valid, do, skip, None)

        ol_s, mask_s, over_s = jax.lax.map(per_slot, (cmeta, valid_s))
        ol = jnp.moveaxis(ol_s, 0, 1)           # (PP, S, G, Mc, Wk)
        mask = jnp.moveaxis(mask_s, 0, 1)       # (PP, S, G, Mc)
        overflow = jax.lax.psum(over_s.sum(), axes)
        cost_pp = (emb_pp * real[None, :].astype(emb_pp.dtype)).sum(1)
        if not sharded:
            return gsup, n_keep, overflow, audit, ol, mask, cost_pp
        # sharded wire: the LPT/rebalance decision moves inside the
        # shard_map (fed by an all-gather of the TINY (NP,) cost
        # vector), and each worker packs its own shard — support slice,
        # replicated scalars + perm, per-shard checksum.  The level's
        # device→host transfer is then 1/W-sized per worker.
        cost = jax.lax.all_gather(cost_pp, axes, axis=0, tiled=True)
        do_reb, imbal, perm = _rebalance(cost)
        shard = _pack_wire(gsup, n_keep, overflow, do_reb, imbal, audit,
                           perm)
        return shard, ol, mask

    n_meta = 3 if fused else 1
    out_specs = ((parts, parts, parts) if sharded
                 else (rep, rep, rep, rep, parts, parts, parts))
    smapped = jax_compat.shard_map(
        core, mesh=mmesh.mesh,
        in_specs=(rep,) * (2 + n_meta) + (parts,) * 5,
        out_specs=out_specs, check_vma=False)

    if sharded:
        program = smapped
    else:
        def program(*args):
            (gsup, n_keep, overflow, audit, ol, mask,
             cost) = smapped(*args)
            do_reb, imbal, perm = _rebalance(cost)
            wire = _pack_wire(gsup, n_keep, overflow, do_reb, imbal,
                              audit, perm)
            return wire, ol, mask

    donate_argnums = ()
    if donate:
        # the parent OL store (after c_real + psup + the meta args).
        # With bucketed shapes the child store matches it exactly, so
        # this is a true arena alias, not just an early free.
        donate_argnums = (2 + n_meta, 3 + n_meta)
    return jax.jit(program, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=64)
def _permute_program(mmesh: MiningMesh):
    """Partition gather applying a wire-reported LPT permutation to the
    whole device-resident store (OL + edge arrays) — dispatched only
    when a rebalance actually fired, so the (rare) all-to-all neither
    taxes every level's compile nor syncs the host.  Inputs are donated:
    the repack replaces the store wholesale."""
    shard = NamedSharding(mmesh.mesh, mmesh.spec_parts())

    def permute(perm, *arrays):
        return tuple(jax.lax.with_sharding_constraint(
            jnp.take(a, perm, axis=0), shard) for a in arrays)

    return jax.jit(permute, donate_argnums=tuple(range(1, 6)))


def permute_stores(mmesh: MiningMesh, perm: np.ndarray, *arrays):
    """Apply the level's LPT permutation to (pol, pmask, src, dst,
    emask) on device.  No host transfer — ``perm`` came home in the
    wire."""
    return _permute_program(mmesh)(jnp.asarray(perm, jnp.int32), *arrays)


def _fetch_wire(wire_d, level: Optional[int], n_partitions: int,
                n_shards: int = 1, packed: bool = False,
                cp: Optional[int] = None) -> np.ndarray:
    """The ONE device→host transfer of a clean level, integrity-checked.

    ``np.array`` (a copy, so jax's cached host value stays pristine even
    when the chaos hook corrupts our view) fetches the packed wire —
    with the sharded layout each worker contributes only its own slice
    to that one gather.  Every shard's trailing checksum word is
    re-computed host-side before any field is decoded.  A mismatch — a
    flipped bit on the host link — triggers a bounded re-fetch from the
    device buffer; persistent mismatch raises
    :class:`~repro.runtime.faults.WireIntegrityError` for the supervisor
    rather than ever decoding corrupt supports."""
    for _ in range(_WIRE_FETCH_ATTEMPTS):
        host = faults.corrupt_wire(np.array(wire_d), level)
        body = reassemble_wire(host, n_partitions, n_shards,
                               packed=packed, cp=cp)
        if body is not None:
            return body
    raise faults.WireIntegrityError(
        f"level wire failed checksum {_WIRE_FETCH_ATTEMPTS}x"
        + (f" at level {level}" if level is not None else ""))


def fetch_wire(wire_d, level: Optional[int] = None) -> np.ndarray:
    """Fetch + verify a DENSE single-shard wire (trailing §10 checksum
    word), with the same bounded re-fetch and chaos hook as the level
    wire.  Used by the device-loop pipeline for its one run wire."""
    return _fetch_wire(wire_d, level, 0, 1, False, None)


def unpack_wire(wire: np.ndarray, C: int, Cp: int, n_partitions: int
                ) -> LevelWire:
    """Decode the (checksum-stripped) wire body by explicit offsets —
    robust to any trailing padding."""
    return LevelWire(
        gsup=wire[:C],
        n_keep=int(wire[Cp]),
        overflow=int(wire[Cp + 1]),
        rebalanced=bool(wire[Cp + 2]),
        imbalance=float(wire[Cp + 3]) / _IMBAL_FX,
        perm=wire[Cp + 5: Cp + 5 + n_partitions],
        audit=int(wire[Cp + 4]),
    )


@dataclasses.dataclass
class PendingLevel:
    """An in-flight level program: dispatched, not yet synced.

    Holds the device-resident futures (JAX dispatches asynchronously, so
    construction returns before the program finishes) plus everything
    the host needs to decode the wire later.  ``finish()`` performs the
    level's single blocking device→host transfer — the driver calls it
    only after it has done the NEXT level's host candidate generation in
    the shadow of this program (DESIGN.md §11)."""

    wire_d: jax.Array          # packed wire (dense or sharded layout)
    pol: jnp.ndarray           # (NP, S, G, M, K+1) — child OLs (future)
    pmask: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray
    emask: jnp.ndarray
    C_real: int
    Cp: int
    n_partitions: int
    n_shards: int              # 1 = dense wire; W = sharded
    level: Optional[int]
    packed: bool = False       # gsup slices ship 2x uint16 per word

    def finish(self) -> LevelOutputs:
        """Block on the wire (the one host sync), verify + decode it."""
        wire = unpack_wire(
            _fetch_wire(self.wire_d, self.level, self.n_partitions,
                        self.n_shards, self.packed, self.Cp),
            self.C_real, self.Cp, self.n_partitions)
        return LevelOutputs(wire, self.pol, self.pmask, self.src,
                            self.dst, self.emask)


def dispatch_level(
    mmesh: MiningMesh,
    meta_p: np.ndarray,       # (Cp, 5) padded candidate metadata (host)
    C_real: int,              # unpadded candidate count
    pol: jnp.ndarray,         # (NP, P, G, M, K) sharded dim0
    pmask: jnp.ndarray,
    src: jnp.ndarray,         # (NP, T, G, F)
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    minsup: int,
    backend: Backend,
    reduce: str,
    max_embeddings: int,
    survivor_cap: int,
    rebalance: bool,
    threshold: float,
    donate: bool,
    child_width: Optional[int] = None,
    sched_floor: Optional[int] = None,
    level: Optional[int] = None,
    sharded: bool = False,
    packed: bool = False,
    tile_c: Optional[int] = None,
    psup: Optional[np.ndarray] = None,
    n_graphs: int = -1,
) -> PendingLevel:
    """Dispatch one level program WITHOUT the host sync.

    The fused backends build the parent-grouped tile schedule host-side
    (same contract as ``map_reduce_supports``), so ``meta_p`` must be
    concrete.  Returns a :class:`PendingLevel`; the caller blocks via
    ``finish()`` when it needs the wire, and owns retry policy
    (escalation / cap miss).

    ``child_width`` is the (bucketed) child vertex-slot width, default
    exact K+1; ``sched_floor`` buckets the fused schedule's row count
    so consecutive levels present one static schedule shape.
    ``sharded`` selects the sharded wire layout (requires
    ``reduce='reduce_scatter'`` and Cp divisible by the worker count).
    ``packed`` selects the bit-packed support path (DESIGN.md §12) —
    the caller guarantees supports fit uint16 (total graph count
    < 2^16).  ``tile_c`` pins the fused schedule's candidate-tile width
    for the run (None = the adaptive per-call choice); the driver pins
    it from the level-2 grouping so the kernel grid — and therefore the
    compiled program — stays constant across levels.

    ``psup`` feeds the device-side invariant audit (DESIGN.md §14): the
    PARENT-indexed support vector, one int32 per slot of the parent
    store's pattern axis in canonical order (-1 = unknown, which skips
    the monotonicity check for candidates of that parent).  It is
    padded to the store's parent axis, so the upload is O(parents) —
    each candidate gathers its parent's support on device through the
    meta parent column.  ``n_graphs`` (the DB graph count) arms the
    support-range check; -1 disables it.  The audit word rides home in
    the wire; a zero word certifies the level passed every check.
    """
    Cp = meta_p.shape[0]
    n_partitions = pol.shape[0]
    W = mmesh.n_workers
    if sharded and Cp % W:
        raise ValueError(
            f"sharded wire needs the padded candidate count divisible by "
            f"the worker count, got Cp={Cp}, W={W} (buckets.candidates / "
            f"round_up_multiple(C, W) guarantee this in the pipeline)")
    # chaos hook: a scheduled in-kernel fault fires here, standing in for
    # an XLA/Mosaic dispatch abort (the supervisor's degradation ladder
    # answers it by swapping backends)
    faults.maybe_raise("kernel", level)
    fn = _level_program(mmesh, minsup, backend, reduce,
                        max_embeddings, survivor_cap, rebalance,
                        threshold, donate, child_width, sharded, packed,
                        n_graphs)
    c_real = jnp.asarray(C_real, jnp.int32)
    # pad to the parent store's pattern axis: the psup length then moves
    # with the same bucket family as pol, costing no extra compiles
    P_axis = pol.shape[1]
    psup_p = np.full((P_axis,), -1, np.int32)
    if psup is not None:
        n_par = min(len(psup), P_axis)
        psup_p[:n_par] = np.asarray(psup, np.int32)[:n_par]
    psup_d = jnp.asarray(psup_p)
    if is_fused_backend(backend):
        from ..kernels.fused_level import DEFAULT_TILE_C
        from .buckets import bucket_size
        from .candgen import pad_schedule, schedule_candidates
        tc = tile_c if tile_c is not None else DEFAULT_TILE_C
        # only the real rows are scheduled (padded candidates would
        # fragment the parent grouping); the row axis is then bucketed
        # with whole invalid tiles and inv parked on one of them.  The
        # bucketed schedule PINS tile_c: the adaptive halving picks a
        # different width per level (a different kernel grid — a
        # recompile); partial-tile waste is bounded by the row bucket
        # and fully-invalid tiles are skipped inside the kernel.  The
        # driver's run-level pin (``tile_c``) replaces the hardwired 8
        # with the level-2 grouping's adaptive choice.
        if sched_floor is not None:
            sched = schedule_candidates(np.asarray(meta_p)[:C_real], tc,
                                        max_inflation=float("inf"))
            rows = bucket_size(sched.meta.shape[0], sched_floor)
        else:
            sched = schedule_candidates(np.asarray(meta_p)[:C_real], tc)
            rows = sched.meta.shape[0]
        sched = pad_schedule(sched, rows_to=rows, inv_to=Cp)
        out = fn(c_real, psup_d, jnp.asarray(sched.meta),
                 jnp.asarray(sched.tiles), jnp.asarray(sched.inv),
                 pol, pmask, src, dst, emask)
    else:
        out = fn(c_real, psup_d, jnp.asarray(meta_p), pol, pmask, src,
                 dst, emask)
    wire_d, new_pol, new_pmask = out
    return PendingLevel(wire_d, new_pol, new_pmask, src, dst, emask,
                        C_real, Cp, n_partitions,
                        W if sharded else 1, level, packed)


def run_level(*args, **kwargs) -> LevelOutputs:
    """Dispatch one level program and perform the single host sync.

    ``dispatch_level(...).finish()`` — the non-overlapped form; same
    signature as :func:`dispatch_level`."""
    return dispatch_level(*args, **kwargs).finish()
