"""Map / shuffle / reduce phases of MIRAGE as shard_map SPMD programs.

One MIRAGE iteration (paper Figs. 7-9) becomes, on a TPU mesh:

  map     — per-device, per-local-partition: fused embedding-join kernel
            over all candidates (kernels/ops.level_supports), summed over
            the device's partitions.  Zero communication: computation
            lives where the data lives.
  shuffle — the dense-key exchange that replaces Hadoop's sort/shuffle:
            the candidate axis is the key space (all devices enumerate the
            identical canonical candidate list), so aggregation is ONE
            collective over a dense int vector:
              * ``psum``            — baseline (paper-faithful reduce)
              * ``reduce_scatter``  — optimized: psum_scatter the support
                vector so each device owns C/W keys (exactly Hadoop's
                "reducer owns a key range"), threshold locally, and
                all_gather the 1-byte verdicts + supports.
  reduce  — threshold + the survivors' child-OL materialization, again
            data-local per partition (pass 2; survivors only).

Why this is the right TPU translation (DESIGN.md §2): a string-keyed
shuffle is a sparse all-to-all — poison on ICI; a dense psum/
reduce-scatter over an agreed key ordering is line-rate.  Agreement costs
nothing because candidate enumeration is deterministic given F_k, which
is globally known at the end of iteration k-1 (the same invariant that
lets Hadoop-MIRAGE read F_k from HDFS).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.bitset import pack_bits, unpack_bits
from ..kernels.ops import (Backend, default_backend, device_local_supports,
                           fused_level_supports, fused_level_supports_packed,
                           is_fused_backend, is_packed_backend)
from ..runtime import jax_compat
from .candgen import schedule_candidates
from .embedding import materialize_ol, LevelOL

__all__ = ["MiningMesh", "map_reduce_supports", "map_materialize",
           "reduce_supports", "worker_imbalance"]


@dataclasses.dataclass(frozen=True)
class MiningMesh:
    """A (possibly multi-axis) mesh with all axes used as workers.

    The paper's "worker" view is 1-D; on a pod the physical mesh is 2-D/3-D
    (("pod",)"data","model").  Mining flattens every axis into the worker
    pool — collectives take the axis-name tuple directly.
    """

    mesh: Mesh

    @property
    def axes(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def n_workers(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    def spec_parts(self) -> P:
        """Partition-major arrays: shard dim 0 over every mesh axis."""
        return P(self.axes)

    def replicated(self) -> P:
        return P()

    @staticmethod
    def single_device() -> "MiningMesh":
        return MiningMesh(jax_compat.make_mesh((1,), ("w",)))


def worker_imbalance(cost, n_workers: int):
    """max/mean per-worker cost under the blocked partition→worker
    assignment, as a traced jnp scalar (1.0 when the mesh is idle).
    Shared by the level program's rebalance trigger and the device
    loop's per-level stats row so the two report identical signals."""
    per_worker = cost.astype(jnp.float32).reshape(n_workers, -1).sum(-1)
    mean = per_worker.mean()
    return jnp.where(mean > 0, per_worker.max() / mean, jnp.float32(1.0))


def reduce_supports(local_sup, axes, minsup: int, reduce: str, *,
                    gather_gsup: bool = False, packed: bool = False):
    """The shuffle: dense-key aggregation of (C,) local supports.

    With ``gather_gsup`` the support counts are all-gathered alongside
    the verdicts in the reduce_scatter variant — the single-sync level
    program needs the full vector on every device to pack the wire;
    the legacy two-program driver leaves them sharded (the host
    reassembles lazily when reading the output array).

    With ``packed`` the reduce_scatter verdict exchange ships bit-packed
    lanes (DESIGN.md §12): each worker packs its C/W verdict shard into
    ``ceil(C/W/32)`` uint32 words, the all-gather moves words instead of
    int8 lanes (8x smaller payload), and each shard unpacks ragged
    (masking pad bits past its C/W tail) before concatenation — the
    returned verdict vector is bit-identical to the dense exchange.
    """
    if reduce == "psum":
        gsup = jax.lax.psum(local_sup, axes)                      # (C,)
        verdict = (gsup >= minsup).astype(jnp.int8)
    elif reduce == "reduce_scatter":
        # each worker owns a contiguous key shard (C/W keys) —
        # Hadoop's "reducer owns a key range", as one collective.
        # Only the 1-byte verdicts are all-gathered (plus the supports
        # when the caller asks); wire per key:
        # (4+1)·(W-1)/W bytes vs psum's 8·(W-1)/W.
        gsup = jax.lax.psum_scatter(
            local_sup, axes, scatter_dimension=0, tiled=True)      # (C/W,)
        if packed:
            cs = gsup.shape[0]
            words = pack_bits(gsup >= minsup)              # (ceil(cs/32),)
            gathered = jax.lax.all_gather(
                words, axes, axis=0, tiled=True)           # (W·ww,)
            shards = gathered.reshape(-1, words.shape[0])  # (W, ww)
            verdict = unpack_bits(shards, cs).reshape(-1).astype(jnp.int8)
        else:
            v_shard = (gsup >= minsup).astype(jnp.int8)
            verdict = jax.lax.all_gather(v_shard, axes, axis=0, tiled=True)
        if gather_gsup:
            gsup = jax.lax.all_gather(gsup, axes, axis=0, tiled=True)
    else:
        raise ValueError(f"unknown reduce {reduce!r}")
    return gsup, verdict


@functools.lru_cache(maxsize=64)
def _support_program(mmesh: MiningMesh, minsup: int,
                     backend: Optional[Backend], reduce: str):
    """Build (once per static config) the jitted SPMD support round —
    per-level shape changes then hit jit's own cache, not a rebuild."""
    axes = mmesh.axes
    parts = mmesh.spec_parts()
    rep = mmesh.replicated()

    def program(meta, pol, pmask, src, dst, emask):
        local_sup, _local_emb, emb_pp = device_local_supports(
            meta, pol, pmask, src, dst, emask, backend=backend)
        gsup, verdict = reduce_supports(local_sup, axes, minsup, reduce)
        return gsup, verdict, emb_pp

    sup_spec = rep if reduce == "psum" else parts
    # check_vma=False: the varying-axis checker cannot see that a tiled
    # all_gather output is device-invariant; semantics are unchanged.
    return jax.jit(jax_compat.shard_map(
        program, mesh=mmesh.mesh,
        in_specs=(rep, parts, parts, parts, parts, parts),
        out_specs=(sup_spec, rep, parts), check_vma=False))


@functools.lru_cache(maxsize=64)
def _support_program_fused(mmesh: MiningMesh, minsup: int,
                           backend: Backend, reduce: str):
    """Fused map phase: ONE kernel launch per device covers every local
    partition and every candidate tile (no per-partition vmap, no (C, G)
    HBM intermediates).  Inputs are in scheduled (parent-grouped) order;
    the inverse permutation is applied on-device before the collective so
    the shuffle and the caller both see canonical candidate order."""
    axes = mmesh.axes
    parts = mmesh.spec_parts()
    rep = mmesh.replicated()
    interpret = backend.endswith("interpret")
    packed = is_packed_backend(backend)

    def program(sched_meta, tiles, inv, pol, pmask, src, dst, emask):
        if packed:
            sup_pp, emb_pp_s, _vbits = fused_level_supports_packed(
                sched_meta, tiles, pol, pmask, src, dst, emask,
                interpret=interpret)                # (PP, Cs) scheduled
        else:
            sup_pp, emb_pp_s = fused_level_supports(
                sched_meta, tiles, pol, pmask, src, dst, emask,
                interpret=interpret)                # (PP, Cs) scheduled
        local_sup = jnp.take(sup_pp.sum(0), inv)    # (C,) canonical
        emb_pp = jnp.take(emb_pp_s, inv, axis=1)    # (PP, C) canonical
        gsup, verdict = reduce_supports(local_sup, axes, minsup, reduce)
        return gsup, verdict, emb_pp

    sup_spec = rep if reduce == "psum" else parts
    return jax.jit(jax_compat.shard_map(
        program, mesh=mmesh.mesh,
        in_specs=(rep, rep, rep, parts, parts, parts, parts, parts),
        out_specs=(sup_spec, rep, parts), check_vma=False))


def map_reduce_supports(
    mmesh: MiningMesh,
    meta: np.ndarray,         # (C, 5) host metadata, replicated on device
    pol: jnp.ndarray,         # (NP, P, G, M, K) sharded dim0
    pmask: jnp.ndarray,       # (NP, P, G, M)
    src: jnp.ndarray,         # (NP, T, G, F)
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    minsup: int,
    backend: Optional[Backend] = None,
    reduce: str = "psum",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One full map+shuffle+reduce support round.

    Returns (global_support (C,), frequent_verdict (C,), per-partition
    embed counts (NP, C)) as host numpy, in canonical candidate order
    regardless of backend.  The reduce_scatter variant needs the
    candidate axis divisible by the worker count (``psum_scatter`` with
    ``tiled=True`` splits it evenly); when C is not, the metadata is
    transparently padded (the same rows ``mining.py`` pads with) and
    every output is sliced back to C — per-candidate supports are
    independent, so padding rows cannot leak.  The fused backends build the
    parent-grouped tile schedule here, host-side, so ``meta`` must be
    concrete (numpy or committed device array).
    """
    backend = backend or default_backend()
    meta = np.asarray(meta)
    C = meta.shape[0]
    W = mmesh.n_workers
    if reduce == "reduce_scatter" and C % W:
        pad = W - C % W
        meta = np.concatenate(
            [meta, np.tile([[0, 0, 0, 1, 0]], (pad, 1))]).astype(meta.dtype)
    if is_fused_backend(backend):
        sched = schedule_candidates(meta)
        fn = _support_program_fused(mmesh, minsup, backend, reduce)
        gsup, verdict, emb_pp = fn(
            jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
            jnp.asarray(sched.inv), pol, pmask, src, dst, emask)
    else:
        fn = _support_program(mmesh, minsup, backend, reduce)
        gsup, verdict, emb_pp = fn(jnp.asarray(meta), pol, pmask, src,
                                   dst, emask)
    return (np.asarray(gsup)[:C], np.asarray(verdict)[:C],
            np.asarray(emb_pp)[:, :C])


@functools.lru_cache(maxsize=64)
def _materialize_program(mmesh: MiningMesh, max_embeddings: int,
                         out_width: Optional[int]):
    axes = mmesh.axes
    parts = mmesh.spec_parts()
    rep = mmesh.replicated()

    def program(meta, pol, pmask, src, dst, emask):
        def per_part(po, pm, s, d, e):
            lvl, over = materialize_ol(
                LevelOL(po, pm), s, d, e, meta,
                max_embeddings=max_embeddings, out_width=out_width)
            return lvl.ol, lvl.mask, over.sum()
        ol, mask, over = jax.vmap(per_part)(pol, pmask, src, dst, emask)
        return ol, mask, jax.lax.psum(over.sum(), axes)

    return jax.jit(jax_compat.shard_map(
        program, mesh=mmesh.mesh,
        in_specs=(rep, parts, parts, parts, parts, parts),
        out_specs=(parts, parts, rep)))


def map_materialize(
    mmesh: MiningMesh,
    keep_meta: jnp.ndarray,   # (C', 5) replicated — surviving candidates
    pol: jnp.ndarray,         # (NP, P, G, M, K)
    pmask: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    emask: jnp.ndarray,
    *,
    max_embeddings: int,
    out_width: Optional[int] = None,
) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Pass 2: build next level's OL store for survivors (data-local; the
    only collective is the overflow-telemetry psum).  ``out_width``
    forwards the bucketed child vertex-slot width (None = exact K+1)."""
    fn = _materialize_program(mmesh, max_embeddings, out_width)
    ol, mask, overflow = fn(keep_meta, pol, pmask, src, dst, emask)
    return ol, mask, int(np.asarray(overflow))
