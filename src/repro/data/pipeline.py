"""Deterministic, shardable, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — the pipeline
cursor IS the step counter, so checkpoint/restore and elastic re-sharding
are free: a restarted job with a different dp-shard count regenerates
exactly the same global batch.

The token stream has learnable structure (noisy affine next-token rule
over the vocab) so end-to-end examples show loss actually falling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    mult: int = 31
    offset: int = 7

    def _rows(self, step: int, rows: np.ndarray) -> np.ndarray:
        """(len(rows), seq_len+1) tokens for global row indices."""
        out = np.empty((len(rows), self.seq_len + 1), np.int64)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 65_537 + int(r))
            toks = np.empty(self.seq_len + 1, np.int64)
            toks[0] = rng.integers(0, self.vocab)
            nz = rng.random(self.seq_len) < self.noise
            rnd = rng.integers(0, self.vocab, self.seq_len)
            for t in range(self.seq_len):
                nxt = (toks[t] * self.mult + self.offset) % self.vocab
                toks[t + 1] = rnd[t] if nz[t] else nxt
            out[i] = toks
        return out

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1
              ) -> dict[str, np.ndarray]:
        """Local slice of the global batch for this dp shard."""
        assert self.global_batch % n_shards == 0
        per = self.global_batch // n_shards
        rows = np.arange(shard * per, (shard + 1) * per)
        toks = self._rows(step, rows)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
