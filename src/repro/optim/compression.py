"""Int8 error-feedback gradient compression for the DP all-reduce.

The distributed-optimization trick for bandwidth-bound data parallelism:
quantize each gradient leaf to int8 with a per-leaf scale before the
cross-replica psum, dequantize after, and carry the quantization residual
into the next step (error feedback keeps the scheme unbiased in the long
run — Seide et al. / Karimireddy et al.).

Used by the explicit-DP trainer (`train_step_ddp`) built on shard_map,
where the gradient collective is under our control (the pjit path lets
XLA schedule its own reductions).  4× wire-byte reduction on the grad
psum at the cost of one quant/dequant pass — §Perf evaluates it on the
collective-bound cell.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["compress_psum", "init_error_state", "make_train_step_ddp"]


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads: Any, err: Any, axis_names) -> tuple[Any, Any]:
    """Error-feedback int8 psum over ``axis_names``.

    Returns (averaged grads, new error state).  Scales are psum'd in
    f32 (negligible bytes); payload crosses the wire as int8.
    """
    import numpy as np
    n = 1
    # axis sizes resolved inside shard_map via psum of 1
    ones = jax.lax.psum(jnp.ones(()), axis_names)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quant(g)
        # int8 payload summed across replicas (values stay in int32 range:
        # 127 * replicas < 2^31 for any realistic pod)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        ssum = jax.lax.psum(scale, axis_names)   # mean scale proxy
        scale_mean = ssum / ones
        g_hat = qsum.astype(jnp.float32) * scale_mean / ones
        new_e = g - q.astype(jnp.float32) * scale
        return g_hat, new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    gh = jax.tree_util.tree_unflatten(td, [o[0] for o in outs])
    ne = jax.tree_util.tree_unflatten(td, [o[1] for o in outs])
    return gh, ne


def make_train_step_ddp(cfg, opt_cfg, loss_fn, mesh, *,
                        compress: bool = True) -> Callable:
    """Explicit data-parallel train step via shard_map: params replicated,
    batch sharded over all mesh axes, grad reduction by (optionally
    compressed) psum.  This is the trainer variant whose collective
    schedule we own end-to-end — the gradient-compression testbed."""
    from ..optim.adamw import adamw_update
    from ..runtime import jax_compat
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)

    def step(params, opt_state, err, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if compress:
            grads, err = compress_psum(grads, err, axes)
        else:
            grads = jax.lax.pmean(grads, axes)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, err, {**metrics, **om, "loss":
                                        jax.lax.pmean(loss, axes)}

    rep = P()
    batch_spec = P(axes)
    return jax.jit(jax_compat.shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_vma=False))
