"""AdamW (pure JAX, no optax) with global-norm clipping and schedules.

Optimizer state inherits each parameter's sharding (ZeRO-style: params
are already FSDP+TP sharded by the PartitionSpec rules, so m/v shard
identically for free — see runtime/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"          # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: final decay fraction of run
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """LR schedules incl. MiniCPM's Warmup-Stable-Decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        mult = jnp.float32(1.0)
    elif cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        mult = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1 - cfg.decay_frac)
        t = jnp.clip((step - decay_start)
                     / max(cfg.total_steps - decay_start, 1), 0, 1)
        mult = 1 - (1 - cfg.min_lr_frac) * t       # stable then linear decay
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * mult


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (1-D leaves)."""
    return True


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = jnp.float32(0)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p32
        return (p32 - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    unflat = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return unflat(new_p), {"m": unflat(new_m), "v": unflat(new_v),
                           "step": step}, metrics
