"""qwen2.5-14b [dense]: 48L d=5120 40H (kv=8) ff=13824 vocab=152064,
GQA with QKV bias.  [hf:Qwen/Qwen2.5-14B]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=13824,
    vocab=152_064, qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=192,
        vocab=512, remat="none")
