"""minicpm-2b [dense]: 40L d=2304 36H (kv=36) ff=5760 vocab=122753,
llama-like; trained with the WSD schedule (optim/schedules.py).
[arXiv:2404.06395]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv=36, d_ff=5760,
    vocab=122_753, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=72, n_heads=6, n_kv=6, d_ff=144,
        vocab=512, remat="none")
