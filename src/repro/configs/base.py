"""Model/config system: one frozen dataclass covers every assigned family.

Each ``configs/<id>.py`` exposes:
  CONFIG          — the exact published architecture
  smoke_config()  — a reduced same-family variant for CPU smoke tests

``registry.get(name)`` resolves ``--arch <id>`` everywhere (launcher,
dry-run, benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_lowers"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None      # defaults to d_model // n_heads
    mlp: str = "swiglu"               # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- gemma2-style extras
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global: bool = False        # alternate local/global attention
    post_norms: bool = False          # gemma2 post-attn/post-ffn norms
    # --- MoE
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1                # MoE layer cadence (1 = all)
    first_dense: int = 0              # leading dense layers (deepseek)
    router_aux_coef: float = 0.001
    # --- MLA (deepseek)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM / hybrid / xLSTM
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    d_conv: int = 4
    ssm_chunk: int = 256
    hybrid_attn_every: int = 0        # zamba2: shared attn block cadence
    slstm_every: int = 0              # xlstm: sLSTM cadence (0 = none)
    # --- enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 0           # precomputed frame embeddings (stub)
    # --- VLM (qwen2-vl)
    mrope_sections: Optional[tuple[int, int, int]] = None
    vision_tokens: int = 0            # precomputed patch embeddings (stub)
    # --- attention execution (perf levers; see EXPERIMENTS.md §Perf)
    attn_schedule: str = "full"       # full | tri (triangular causal skip)
    q_chunk: int = 512
    kv_chunk: int = 1024
    prefill_logits: str = "all"       # all | last (serving returns 1 pos)
    seq_parallel: bool = False        # sequence-sharded residual stream
    moe_impl: str = "einsum"          # einsum (GShard) | scatter
    capacity_factor: float = 1.25
    # --- numerics
    dtype: str = "bfloat16"
    remat: str = "block"              # none | block (checkpoint each block)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear-attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline term)."""
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_lowers(shape: ShapeConfig) -> str:
    """Which step function a shape lowers (assignment rules)."""
    return {"train": "train_step", "prefill": "prefill_step",
            "decode": "decode_step"}[shape.kind]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 512k-token decode needs "
                       "sub-quadratic attention (documented skip)")
    return True, ""
