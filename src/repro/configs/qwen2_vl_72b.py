"""qwen2-vl-72b [vlm]: 80L d=8192 64H (kv=8) ff=29568 vocab=152064,
M-RoPE (t/h/w sections 16/24/24 of the 64-dim rotary half), QKV bias.
Vision patch frontend is a stub: input_specs provides precomputed patch
embeddings + 3-axis positions.  [arXiv:2409.12191]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568,
    vocab=152_064, qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=192,
        vocab=512, mrope_sections=(4, 2, 2), remat="none")
