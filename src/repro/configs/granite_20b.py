"""granite-20b [dense, code]: 52L d=6144 48H MQA (kv=1) ff=24576 (4x GELU,
gpt-bigcode lineage) vocab=49152.  [arXiv:2405.04324]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576,
    vocab=49152, mlp="gelu", qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=256,
        vocab=256, remat="none")
