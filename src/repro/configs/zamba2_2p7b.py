"""zamba2-2.7b [hybrid]: 54 Mamba2 layers, d=2560, ssm_state=64, with ONE
shared attention block (32H, kv=32) applied every 6 layers.
[arXiv:2411.15242]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_expand=2, ssm_heads=80, d_conv=4,
    hybrid_attn_every=6, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=256,
        vocab=256, ssm_state=16, ssm_heads=4, hybrid_attn_every=2,
        ssm_chunk=8, remat="none")
