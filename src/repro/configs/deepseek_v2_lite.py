"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H, MLA (kv_lora=512,
qk_nope=128, qk_rope=64, v_head=128), MoE 64 routed experts top-6 +
2 shared, expert ff=1408, first layer dense, vocab=102400.

Assignment-spec note (see DESIGN.md §7): the spec line lists both
"64e top-6" and "160 routed"; 160 routed belongs to full V2 — we follow
the leading spec (64 routed / top-6 / 2 shared).  [arXiv:2405.04434]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, d_ff=10944,
    vocab=102_400, mla=True, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, d_head=192,
    n_experts=64, n_shared=2, top_k=6, d_ff_expert=1408, first_dense=1,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=512, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, d_head=24, n_experts=8, n_shared=1, top_k=2,
        d_ff_expert=32, first_dense=1, remat="none")
