"""xlstm-1.3b [ssm]: 48 blocks d=2048 4H, mLSTM backbone with sLSTM every
8th block (d_ff=0: blocks carry their own projections).
[arXiv:2405.04517]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    slstm_every=8, ssm_chunk=256, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=4, vocab=256,
        slstm_every=2, ssm_chunk=8, remat="none")
