"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (kv=8), 16 experts top-2,
expert ff=6400, vocab=32064.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400,
    vocab=32_064, n_experts=16, n_shared=0, top_k=2, d_ff_expert=6400,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=256, n_experts=4, top_k=2, d_ff_expert=64, remat="none")
