"""whisper-base [audio]: enc-dec, 6L dec + 6L enc, d=512, 8H (kv=8),
ff=2048, vocab=51865.  Conv frontend is a stub: input_specs provides
precomputed mel-frame embeddings (B, 1500, 512).  [arXiv:2212.04356]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048, vocab=51865,
    mlp="gelu", encoder_layers=6, encoder_frames=1500,
    rope_theta=10_000.0, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=256, encoder_layers=2, encoder_frames=16, remat="none")
