"""gemma2-2b [dense]: 26L d=2304 8H (kv=4) ff=9216, vocab=256000,
alternating local(4096-window)/global attention, attn softcap 50, final
softcap 30, post-norms, tied embeddings.  [arXiv:2408.00118]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv=4, d_ff=9216,
    vocab=256_000, d_head=256, local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=256,
        vocab=512, d_head=16, sliding_window=8, remat="none")
