"""Training step assembly: value_and_grad + AdamW + optional microbatch
gradient accumulation, built from a registry loss_fn."""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(params: Any) -> dict:
    return adamw_init(params)


def make_train_step(cfg, opt_cfg: AdamWConfig, loss_fn: Callable,
                    *, microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatches > 1`` accumulates grads over leading batch
    splits via lax.scan (activation memory / global batch decoupling)."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x, axis=0):
                b = x.shape[axis]
                return x.reshape(x.shape[:axis]
                                 + (microbatches, b // microbatches)
                                 + x.shape[axis + 1:]).swapaxes(0, axis)
            # positions3 is (3, B, S): its batch dim is axis 1
            mb = {k: split(v, 1 if k == "positions3" else 0)
                  for k, v in batch.items()}

            def acc_body(carry, mbatch):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, mbatch)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + l), m

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                acc_body, (zero, jnp.float32(0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grads)
            loss = loss / microbatches
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)

        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return train_step
