"""End-to-end training loop: data pipeline + train_step + checkpointing.

Fault tolerance contract (mirrors the mining driver's):
  * checkpoint every ``ckpt_every`` steps: params, optimizer state, step
    (the data-pipeline cursor IS the step — the pipeline is a pure
    function of it);
  * ``resume=True`` restarts from the newest complete checkpoint, on a
    possibly different mesh/device count (elastic): state was written
    unsharded, re-laid-out on load;
  * the loop is deterministic: same seed + same global batch schedule
    regardless of shard count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import TokenPipeline
from ..optim.adamw import AdamWConfig
from ..runtime import checkpoint as ckpt
from ..runtime.sharding import active_mesh, param_shardings
from .train_step import init_train_state, make_train_step

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0


def train_loop(cfg, fns: dict, loop_cfg: TrainLoopConfig,
               opt_cfg: AdamWConfig, pipeline: TokenPipeline,
               *, mesh=None, resume: bool = False,
               extra_batch: Optional[Callable[[int], dict]] = None
               ) -> dict:
    """Returns {"losses": [...], "params": ..., "steps_run": int}."""
    step0 = 0
    params = opt_state = None

    if resume and loop_cfg.ckpt_dir and ckpt.latest_step(loop_cfg.ckpt_dir):
        state, meta = ckpt.load_step(loop_cfg.ckpt_dir)
        params, opt_state = state["params"], state["opt"]
        params = jax.tree_util.tree_map(jnp.asarray, params)
        opt_state = jax.tree_util.tree_map(jnp.asarray, opt_state)
        step0 = int(meta["step"])
    else:
        params = fns["init"](jax.random.key(loop_cfg.seed))
        opt_state = init_train_state(params)

    if mesh is not None:
        shardings = param_shardings(params, mesh)
        params = jax.device_put(params, shardings)
        opt_state = {
            "m": jax.device_put(opt_state["m"], shardings),
            "v": jax.device_put(opt_state["v"], shardings),
            "step": opt_state["step"],
        }

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, fns["loss_fn"],
                                      microbatches=loop_cfg.microbatches),
                      donate_argnums=(0, 1))

    losses = []
    ctx = active_mesh(mesh) if mesh is not None else active_mesh(None)
    with ctx:
        for step in range(step0, loop_cfg.steps):
            batch = pipeline.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if extra_batch is not None:
                batch.update({k: jnp.asarray(v)
                              for k, v in extra_batch(step).items()})
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % loop_cfg.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if (loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0):
                ckpt.save_step(
                    loop_cfg.ckpt_dir, step + 1,
                    {"params": jax.tree_util.tree_map(np.asarray, params),
                     "opt": jax.tree_util.tree_map(np.asarray, opt_state)},
                    metadata={"kind": "train", "loss": loss})
    return {"losses": losses, "params": params, "opt": opt_state,
            "steps_run": loop_cfg.steps - step0}
