"""Roofline terms per (arch × shape × mesh) from a compiled dry-run.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

(The compiled module is already the per-device SPMD program, so terms are
per-chip directly.)  MODEL_FLOPS uses the assignment's analytic form —
6·N·D for training (N = params, MoE: active params; D = tokens), 2·N·D
for prefill, 2·N·B for decode — and the ratio MODEL_FLOPS/HLO_FLOPs
measures how much compiled compute is "useful" (remat, attention-schedule
waste, dispatch overhead all show up here).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

from ..configs.base import ModelConfig, ShapeConfig
from .hlo import HloCost, parse_hlo_cost
from .hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

__all__ = ["RooflineReport", "analyze"]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    step: str
    # per-device HLO-derived
    hlo_flops: float
    hlo_bytes: float                  # instruction-walk proxy (upper bound)
    analytic_bytes_dev: float         # first-order HBM model (see analytic_bytes)
    wire_bytes: float
    collectives: dict
    n_dots: int
    unknown_trip_whiles: int
    # terms (seconds)
    t_compute: float
    t_memory: float                   # from analytic_bytes_dev
    t_memory_hlo_proxy: float
    t_collective: float
    bottleneck: str
    # analytic
    model_flops_global: float
    model_flops_per_chip: float
    useful_ratio: float               # model_flops / hlo_flops (per chip)
    roofline_fraction: float          # t_dominant_useful / t_total estimate
    # memory
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    # bookkeeping
    cost_analysis_flops: Optional[float] = None
    notes: str = ""
    collective_sites: Optional[list] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic 'useful' FLOPs per step (global)."""
    n = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * B * S
    if shape.kind == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B            # decode: one token per sequence


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                   tp: int, microbatches: int) -> float:
    """First-order per-device HBM traffic per step (documented model).

    The HLO instruction walk (``bytes_hbm``) over-counts real HBM traffic
    badly (~100×): it charges every scheduled instruction's operands even
    when XLA keeps them register/VMEM-resident across the loop body.  The
    roofline memory term therefore uses this analytic model:

      train:   weights (bf16/tp) × μ × 3 (fwd + bwd + remat re-read)
               + optimizer update (fp32 p/m/v/g, r+w) on the (dp·tp) shard
               + block activations × C_ACT (remat: block inputs only)
      prefill: weights × 1 + activations × C_ACT
      decode:  weights × 1 + full KV/state cache read + write-back

    C_ACT = 16 charges ~16 d_model-wide residual-stream buffers per
    layer per token (block in/out, norms, qkv/o, mlp io).  Chunked
    attention keeps (qc × kc) score tiles in VMEM — no S² HBM term.
    """
    n_total = cfg.param_count()
    dp = chips // tp
    B, S = shape.global_batch, shape.seq_len
    C_ACT = 16
    L = cfg.n_layers + cfg.encoder_layers
    d = cfg.d_model
    w_bf16 = 2.0 * n_total / tp

    if shape.kind == "train":
        tokens_dev = B * S / dp
        weights = w_bf16 * microbatches * 3
        opt = (4.0 * n_total / chips) * 8
        acts = tokens_dev * d * 2 * L * C_ACT
        return weights + opt + acts
    if shape.kind == "prefill":
        tokens_dev = B * S / dp
        return w_bf16 + tokens_dev * d * 2 * L * C_ACT
    # decode: read the whole cache once + weights once
    if cfg.mla:
        cache_row = cfg.kv_lora + cfg.qk_rope_dim
        cache = B * S * cache_row * 2 * cfg.n_layers
    elif cfg.family == "ssm":
        H, D = cfg.n_heads, cfg.d_model // cfg.n_heads
        cache = B * H * D * D * 4 * cfg.n_layers
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        Hs = cfg.ssm_heads or d_in // 64
        P = d_in // Hs
        cache = (B * Hs * cfg.ssm_state * P * 4 * cfg.n_layers
                 + B * S * cfg.n_kv * cfg.head_dim * 2 * 2
                 * (cfg.n_layers // max(cfg.hybrid_attn_every, 1)))
    else:
        cache = B * S * cfg.n_kv * cfg.head_dim * 2 * 2 * cfg.n_layers
    return w_bf16 + 2.0 * cache / chips


def analyze(cfg: ModelConfig, shape: ShapeConfig, *, mesh_name: str,
            chips: int, step: str, hlo_text: str,
            memory_stats: Any = None,
            cost_analysis: Optional[dict] = None,
            tp: int = 16, microbatches: int = 1,
            notes: str = "") -> RooflineReport:
    cost: HloCost = parse_hlo_cost(hlo_text)
    ab = analytic_bytes(cfg, shape, chips=chips, tp=tp,
                        microbatches=microbatches)
    t_c = cost.flops / PEAK_FLOPS_BF16
    t_m = ab / HBM_BW
    t_m_proxy = cost.bytes_hbm / HBM_BW
    t_x = cost.collective_wire_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    mf_chip = mf / chips
    useful = mf_chip / cost.flops if cost.flops else 0.0
    # fraction of the roofline the useful work achieves if the dominant
    # term fully serializes (conservative; no overlap assumed)
    t_useful = mf_chip / PEAK_FLOPS_BF16
    t_total = max(terms.values())
    frac = t_useful / t_total if t_total > 0 else 0.0

    mem = memory_stats
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        step=step,
        hlo_flops=cost.flops, hlo_bytes=cost.bytes_hbm,
        analytic_bytes_dev=ab,
        wire_bytes=cost.collective_wire_bytes,
        collectives={k: {"count": v[0], "wire_bytes": v[1]}
                     for k, v in cost.collectives.items()},
        n_dots=cost.n_dots, unknown_trip_whiles=cost.unknown_trip_whiles,
        t_compute=t_c, t_memory=t_m, t_memory_hlo_proxy=t_m_proxy,
        t_collective=t_x, bottleneck=bottleneck,
        model_flops_global=mf, model_flops_per_chip=mf_chip,
        useful_ratio=useful, roofline_fraction=frac,
        argument_bytes=getattr(mem, "argument_size_in_bytes", 0) if mem else 0,
        output_bytes=getattr(mem, "output_size_in_bytes", 0) if mem else 0,
        temp_bytes=getattr(mem, "temp_size_in_bytes", 0) if mem else 0,
        cost_analysis_flops=(cost_analysis or {}).get("flops"),
        notes=notes,
        collective_sites=[[k, v] for k, v in cost.top_sites()],
    )
