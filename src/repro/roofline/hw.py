"""Target-hardware constants (TPU v5e, per assignment)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 1, "u4": 1,  # round up
}
