"""HLO text cost model with loop-trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically — a 10-step scan reports 1/10th the FLOPs), which makes it
useless for scan-over-layers models.  This module re-derives per-device
cost by walking the optimized HLO text:

  * ``dot`` FLOPs = 2 · |output| · prod(contracted dims), multiplied by
    the product of enclosing loop trip counts (from the while op's
    ``backend_config.known_trip_count``; dynamic-trip loops are counted
    once and surfaced in ``unknown_trip_whiles``);
  * collective payloads (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute, incl. -start forms) with
    replica-group sizes, converted to wire bytes with standard ring
    factors;
  * HBM-traffic proxy: Σ (operand + output bytes) over materializing
    top-level instructions — an upper bound that treats each scheduled
    instruction's buffers as HBM-resident (fusion internals excluded).

The HLO here is the *per-device* SPMD program, so every figure is
per-chip; divide nothing.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

from .hw import DTYPE_BYTES

__all__ = ["HloCost", "parse_hlo_cost"]

_COMP_RE = re.compile(
    r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> .+ \{\s*$")
# shape segment may contain tuple parens and /*index=N*/ comments; the op
# token is the first bare word immediately followed by '('
_INST_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = (.*?) ([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# wire-byte multiplier per payload byte for a ring algorithm over N chips
def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0          # collective-permute


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    rest: str
    operands: list[str]


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_payload_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    collectives_by_site: dict = dataclasses.field(default_factory=dict)
    n_dots: int = 0
    unknown_trip_whiles: int = 0
    convolutions: int = 0

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_hbm += other.bytes_hbm * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        self.collective_payload_bytes += (other.collective_payload_bytes
                                          * mult)
        for k, v in other.collectives.items():
            e = self.collectives.setdefault(k, [0, 0.0])
            e[0] += v[0] * mult
            e[1] += v[1] * mult
        for k, v in other.collectives_by_site.items():
            self.collectives_by_site[k] = (
                self.collectives_by_site.get(k, 0.0) + v * mult)
        self.n_dots += int(other.n_dots * mult)
        self.unknown_trip_whiles += other.unknown_trip_whiles
        self.convolutions += other.convolutions

    def top_sites(self, n: int = 12) -> list[tuple[str, float]]:
        return sorted(self.collectives_by_site.items(),
                      key=lambda kv: -kv[1])[:n]


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota"}


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: Optional[str] = None
    sym: dict[str, str] = {}
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        # operand names: %foo references inside the parens (first level)
        depth, i, args_end = 1, 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args_end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", rest[:args_end])
        comps[cur].append(_Instr(name, shape.strip(), op, rest, operands))
    return comps


def parse_hlo_cost(text: str) -> HloCost:
    comps = _parse_computations(text)
    entry = None
    for m in re.finditer(r"^ENTRY %?([\w.\-]+)", text, re.M):
        entry = m.group(1)
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1]

    memo: dict[str, HloCost] = {}

    def comp_cost(cname: str) -> HloCost:
        if cname in memo:
            return memo[cname]
        memo[cname] = HloCost()          # cycle guard
        cost = HloCost()
        instrs = comps.get(cname, [])
        sym = {i.name: i.shape for i in instrs}

        for ins in instrs:
            op = ins.op
            if op == "dot":
                out_elems = _shape_elems(ins.shape)
                lhs_shape = sym.get(ins.operands[0], "") if ins.operands \
                    else ""
                cdims = _CONTRACT_RE.search(ins.rest)
                contracted = 1
                if cdims and lhs_shape:
                    m = _SHAPE_RE.search(lhs_shape)
                    if m and m.group(2):
                        dims = [int(x) for x in m.group(2).split(",")]
                        idxs = [int(x) for x in cdims.group(1).split(",")
                                if x != ""]
                        for ix in idxs:
                            if ix < len(dims):
                                contracted *= dims[ix]
                cost.flops += 2.0 * out_elems * contracted
                cost.n_dots += 1
            elif op == "convolution":
                cost.convolutions += 1
            elif op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    cost.unknown_trip_whiles += 1
                for ref in _CALL_ATTR_RE.findall(ins.rest):
                    cost.add(comp_cost(ref), trip)
                continue
            elif op in ("fusion", "call", "custom-call", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter"):
                for ref in _CALL_ATTR_RE.findall(ins.rest):
                    cost.add(comp_cost(ref), 1.0)
            elif op == "conditional":
                br = _BRANCH_RE.search(ins.rest)
                if br:
                    subs = re.findall(r"%?([\w.\-]+)", br.group(1))
                    if subs:
                        costs = [comp_cost(s) for s in subs]
                        worst = max(costs, key=lambda c: c.flops)
                        cost.add(worst, 1.0)
            elif any(op.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.startswith(c))
                if op.endswith("-done"):
                    continue
                payload = _shape_bytes(ins.shape)
                if kind == "reduce-scatter" and ins.operands:
                    payload = _shape_bytes(sym.get(ins.operands[0],
                                                   ins.shape))
                n = 0
                g = _GROUPS_RE.search(ins.rest)
                if g:
                    n = len([x for x in g.group(1).split(",") if x.strip()])
                else:
                    gi = _GROUPS_IOTA_RE.search(ins.rest)
                    if gi:
                        n = int(gi.group(2))
                wire = payload * _wire_factor(kind, max(n, 2))
                cost.collective_payload_bytes += payload
                cost.collective_wire_bytes += wire
                e = cost.collectives.setdefault(kind, [0, 0.0])
                e[0] += 1
                e[1] += wire
                om = _OPNAME_RE.search(ins.rest)
                site = (om.group(1)[-90:] if om else "?")
                cost.collectives_by_site[f"{kind} {site}"] = (
                    cost.collectives_by_site.get(f"{kind} {site}", 0.0)
                    + wire)

            # HBM-traffic proxy
            if op not in _SKIP_BYTES and op != "while":
                b = _shape_bytes(ins.shape)
                for o in ins.operands:
                    b += _shape_bytes(sym.get(o, ""))
                cost.bytes_hbm += b

        memo[cname] = cost
        return cost

    return comp_cost(entry)
