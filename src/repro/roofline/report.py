"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results JSONs.

    python -m repro.roofline.report --results results > /tmp/tables.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(results: str, mesh: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results, "dryrun", mesh,
                                              "*.json"))):
        with open(path) as f:
            d = json.load(f)
        d["_file"] = os.path.basename(path)
        cells.append(d)
    return cells


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | step | status | args GiB/dev | temp GiB/dev "
            "| compile s |",
            "|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("kind") == "mining":
            continue
        if d.get("status") == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | — | SKIP: "
                        f"{d['reason'][:60]}… | — | — | — |")
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['step']} | ok "
            f"| {fmt_bytes(d['argument_bytes'])} "
            f"| {fmt_bytes(d['temp_bytes'])} "
            f"| {d.get('compile_seconds', 0):.0f} |")
    return "\n".join(rows)


def roofline_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | bound "
            "| MODEL_FLOPs/chip | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("status") != "ok" or d.get("kind") == "mining":
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {d['t_compute']:.3f} | {d['t_memory']:.3f} "
            f"| {d['t_collective']:.3f} | {d['bottleneck']} "
            f"| {d['model_flops_per_chip']:.2e} "
            f"| {d['useful_ratio']:.3f} | {d['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def mining_table(cells: list[dict]) -> str:
    rows = ["| mesh | reduce | phase | t_comp s | t_mem s | t_coll s "
            "| bound | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("kind") != "mining":
            continue
        for phase in ("support", "materialize"):
            p = d[phase]
            rows.append(
                f"| {d['mesh']} | {d['reduce']} | {phase} "
                f"| {p['t_compute']:.4f} | {p['t_memory']:.4f} "
                f"| {p['t_collective']:.6f} | {p['bottleneck']} "
                f"| {p['collectives']} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    args = ap.parse_args()
    for mesh in ("single", "multi"):
        cells = load_cells(args.results, mesh)
        if not cells:
            continue
        print(f"\n### Dry-run — {mesh} pod "
              f"({'512' if mesh == 'multi' else '256'} chips)\n")
        print(dryrun_table(cells))
        print(f"\n### Roofline — {mesh} pod\n")
        print(roofline_table(cells))
        mt = mining_table(cells)
        if mt.count("\n") > 1:
            print(f"\n### Mining step — {mesh} pod\n")
            print(mt)


if __name__ == "__main__":
    main()
