"""Multi-worker differential conformance (ISSUE 7 acceptance).

The negative-scaling fix has three moving parts — the sharded support
wire, overlapped candidate generation, and density-based partitioning —
and each must be bit-identical to the host oracle both alone and
composed, at real worker counts.  CPU builds expose W simulated devices
via ``XLA_FLAGS=--xla_force_host_platform_device_count``, which only
takes effect before jax initialises, so every multi-device case runs in
a subprocess (same pattern as tests/test_chaos.py).

In-process (single-device) tests cover the host-side pieces directly:
the sharded wire codec (``wire_words``/``reassemble_wire``), the
deterministic byte model the CI scaling gate checks, density
partitioning, and the speculative-candgen filter equivalence proof.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.candgen import filter_speculative, generate_candidates
from repro.core.graphdb import Graph, random_db
from repro.core.host_miner import mine_host
from repro.core.level_step import (reassemble_wire, wire_checksum,
                                   wire_cost_model, wire_words)
from repro.core.mining import Mirage, MirageConfig
from repro.core.partition import (filter_infrequent_edges, graph_density,
                                  make_partitions)


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# sharded wire codec
# ---------------------------------------------------------------------------

def _make_wire(cp, n_partitions, n_shards, *, seed=0):
    """Synthesize a packed wire exactly as the level program emits it:
    per-shard [gsup slice | 5 scalars | perm | checksum], with the
    scalar words and permutation replicated across shards."""
    rng = np.random.default_rng(seed)
    gsup = rng.integers(0, 100, cp).astype(np.int32)
    scalars = np.array([7, 0, 1, 1 << 15, 0], np.int32)
    perm = np.arange(n_partitions, dtype=np.int32)[::-1].copy()
    shards = []
    for s in np.split(gsup, n_shards):
        body = np.concatenate([s, scalars, perm])
        shards.append(np.concatenate([body, [wire_checksum(body)]]))
    dense_body = np.concatenate([gsup, scalars, perm])
    return np.concatenate(shards).astype(np.int32), dense_body


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_wire_roundtrip_all_shard_counts(n_shards):
    """reassemble_wire inverts the device packing for every shard count,
    and n_shards=1 is bit-identical to the dense layout."""
    cp, n_partitions = 16, 4
    host, dense_body = _make_wire(cp, n_partitions, n_shards)
    assert host.shape[0] == wire_words(cp, n_partitions, n_shards)
    out = reassemble_wire(host, n_partitions, n_shards)
    np.testing.assert_array_equal(out, dense_body)


def test_wire_words_rejects_ragged_shards():
    with pytest.raises(ValueError):
        wire_words(10, 4, n_shards=4)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_wire_corruption_caught_in_any_shard(n_shards):
    """A single flipped bit anywhere in the wire — any shard, any word —
    must fail that shard's checksum and return None (the caller's
    re-fetch signal)."""
    cp, n_partitions = 16, 4
    host, _ = _make_wire(cp, n_partitions, n_shards)
    words = host.shape[0]
    for w in {0, words // 2, words - 1}:
        bad = host.copy()
        bad[w] ^= np.int32(1 << 7)
        assert reassemble_wire(bad, n_partitions, n_shards) is None, w


def test_wire_cost_model_sharding_invariants():
    """The deterministic byte model behind the CI scaling gate: the
    sharded layout's host transfer shrinks ~1/W while the dense layouts
    hold it constant, and sharded total stays below dense total at every
    W > 1."""
    cp, npart = 256, 8
    base = wire_cost_model(cp, npart, 1, reduce="reduce_scatter")
    dense1 = wire_cost_model(cp, npart, 1, reduce="reduce_scatter",
                             sharded=False)
    # W=1: no collective, sharded == dense (one shard IS the dense wire)
    assert base["collective_bytes"] == 0
    assert base["host_bytes"] == dense1["host_bytes"]
    prev_host = base["host_bytes"]
    for w in (2, 4, 8):
        sh = wire_cost_model(cp, npart, w, reduce="reduce_scatter")
        de = wire_cost_model(cp, npart, w, reduce="reduce_scatter",
                             sharded=False)
        ps = wire_cost_model(cp, npart, w, reduce="psum")
        assert sh["host_bytes"] < prev_host          # keeps shrinking
        assert de["host_bytes"] == dense1["host_bytes"]   # dense: flat
        assert ps["host_bytes"] == dense1["host_bytes"]
        assert sh["total_bytes"] < de["total_bytes"]
        assert sh["total_bytes"] < ps["total_bytes"]
        prev_host = sh["host_bytes"]


# ---------------------------------------------------------------------------
# density partitioning
# ---------------------------------------------------------------------------

def test_graph_density_values():
    lone = Graph(vlabels=[0], edges=np.zeros((0, 2)), elabels=[])
    assert graph_density(lone) == 0.0
    tri = Graph(vlabels=[0, 0, 0], edges=[[0, 1], [1, 2], [0, 2]],
                elabels=[0, 0, 0])
    assert graph_density(tri) == 1.0
    path = Graph(vlabels=[0, 0, 0], edges=[[0, 1], [1, 2]],
                 elabels=[0, 0])
    assert graph_density(path) == pytest.approx(2 / 3)


def test_density_scheme_balances_and_orders():
    graphs = random_db(17, n_vertices=7, extra_edge_prob=0.5,
                       n_vlabels=2, n_elabels=2, seed=9)
    res = make_partitions(graphs, 2, 4, scheme="density")
    sizes = [len(ids) for ids in res.graph_ids]
    assert max(sizes) - min(sizes) <= 1               # snake-deal balance
    flat = sorted(i for ids in res.graph_ids for i in ids)
    assert flat == list(range(17))                    # exact cover
    # the deal is density-descending: each partition's first graph came
    # from the first (densest) sweep, so every partition's head graph is
    # at least as dense as its own tail graphs
    filtered, _ = filter_infrequent_edges(graphs, 2)
    for ids in res.graph_ids:
        dens = [graph_density(filtered[i]) for i in ids]
        assert dens[0] >= dens[-1]


def test_unknown_scheme_rejected():
    graphs = random_db(6, n_vertices=5, seed=1)
    with pytest.raises(ValueError, match="density"):
        make_partitions(graphs, 2, 2, scheme="hash")


def test_density_scheme_conformance_single_device():
    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 5, max_size=3)
    res = Mirage(MirageConfig(minsup=5, n_partitions=4, scheme="density",
                              max_size=3)).fit(graphs)
    assert sorted(res.supports.items()) == sorted(
        (c, i.support) for c, i in ref.frequent.items())


# ---------------------------------------------------------------------------
# overlapped candgen: the speculation-filter equivalence proof
# ---------------------------------------------------------------------------

def test_filter_speculative_matches_direct_generation():
    """For ANY survivor subset, narrowing the speculative superset must
    equal generating from the survivors directly — same candidates,
    same order, same (remapped) parent indices.  This is the invariant
    that makes overlapping candgen with the in-flight device program
    semantically free."""
    graphs = random_db(12, n_vertices=6, extra_edge_prob=0.4,
                       n_vlabels=2, n_elabels=2, seed=3)
    _, alphabet = filter_infrequent_edges(graphs, 3)
    f1 = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
    assert len(f1) >= 3
    spec = generate_candidates(f1, alphabet)
    n = len(f1)
    for keep in ([], [0], list(range(0, n, 2)), list(range(n))):
        direct = generate_candidates([f1[i] for i in keep], alphabet)
        assert filter_speculative(spec, keep) == direct, keep


def test_overlap_cost_gate_skips_expensive_speculation(monkeypatch):
    """Speculative candgen runs over the FULL candidate superset; when
    the measured per-parent rate prices it beyond the hiding window the
    driver must skip it (regression: blind speculation made a deep
    sparse-survival run 12x slower than overlap off) — and still mine
    exactly."""
    from repro.core import mining as mining_mod

    graphs = random_db(14, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=2, n_elabels=2, seed=11)
    ref = mine_host(graphs, 4, max_size=3)
    orig = mining_mod.generate_candidates

    def slow(frequent, alphabet):
        import time
        time.sleep(0.2 * len(frequent))        # rate >> the window floor
        return orig(frequent, alphabet)

    monkeypatch.setattr(mining_mod, "generate_candidates", slow)
    res = Mirage(MirageConfig(minsup=4, n_partitions=2, max_size=3,
                              overlap_candgen=True)).fit(graphs)
    # the first level's hiding window is exactly overlap_spec_window
    # (no prior device timing), so the gate decision is deterministic
    # there; later windows include measured device time, which a cold
    # compile legitimately inflates
    assert res.stats[0].candgen_seconds == 0
    assert sorted(res.supports.items()) == sorted(
        (c, i.support) for c, i in ref.frequent.items())


def test_overlap_on_off_bit_identical():
    graphs = random_db(14, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=2, n_elabels=2, seed=11)
    base = dict(minsup=4, n_partitions=2, max_size=4)
    on = Mirage(MirageConfig(overlap_candgen=True, **base)).fit(graphs)
    off = Mirage(MirageConfig(overlap_candgen=False, **base)).fit(graphs)
    assert sorted(on.supports.items()) == sorted(off.supports.items())
    assert [set(l) for l in on.levels] == [set(l) for l in off.levels]
    # the overlapped run actually recorded speculative candgen work
    assert any(st.candgen_seconds > 0 for st in on.stats[:-1])


# ---------------------------------------------------------------------------
# multi-worker conformance matrix (subprocess: W simulated devices)
# ---------------------------------------------------------------------------

MATRIX_SNIPPET = textwrap.dedent("""
    import itertools, os, sys
    W = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={W}")
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    assert jax.device_count() == W
    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 5, max_size=3)
    want = sorted((c, i.support) for c, i in ref.frequent.items())
    mesh = MiningMesh(jax_compat.make_mesh((W,), ("w",)))

    for sharded, scheme, overlap in itertools.product(
            (True, False), (2, "density"), (True, False)):
        cfg = MirageConfig(minsup=5, n_partitions=8, max_size=3,
                           scheme=scheme, reduce="reduce_scatter",
                           sharded_wire=sharded, overlap_candgen=overlap)
        res = Mirage(cfg, mesh).fit(graphs)
        key = (W, sharded, scheme, overlap)
        assert sorted(res.supports.items()) == want, key
        assert [set(l) for l in res.levels] == \\
            [set(l) for l in ref.levels], key
    # psum differential oracle at the same worker count
    res = Mirage(MirageConfig(minsup=5, n_partitions=8, max_size=3,
                              reduce="psum"), mesh).fit(graphs)
    assert sorted(res.supports.items()) == want, (W, "psum")
    print("MATRIX-OK")
""")


@pytest.mark.parametrize("workers", [2, 4, 8])
def test_multiworker_conformance_matrix(workers):
    """sharded-wire x density-partitioning x overlap, all bit-identical
    to the host oracle at W=2,4,8 — plus the psum differential oracle."""
    assert "MATRIX-OK" in _run_snippet(MATRIX_SNIPPET, workers)


# ---------------------------------------------------------------------------
# C % W regression: reduce_scatter with a ragged candidate axis
# ---------------------------------------------------------------------------

RAGGED_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 5, max_size=3)
    want = sorted((c, i.support) for c, i in ref.frequent.items())
    mesh = MiningMesh(jax_compat.make_mesh((2,), ("w",)))

    # legacy pipeline drives map_reduce_supports directly (the path
    # that silently assumed C % W == 0); unbucketed single_sync covers
    # the level-program pad.  Both must see a genuinely odd C.
    for pipeline, extra in (("legacy", {}),
                            ("single_sync", {"bucket_shapes": False})):
        cfg = MirageConfig(minsup=5, n_partitions=8, max_size=3,
                           pipeline=pipeline, reduce="reduce_scatter",
                           **extra)
        res = Mirage(cfg, mesh).fit(graphs)
        assert any(st.n_candidates % 2 for st in res.stats), (
            pipeline, [st.n_candidates for st in res.stats],
            "pick a DB with an odd candidate level")
        assert sorted(res.supports.items()) == want, pipeline
    print("RAGGED-OK")
""")


def test_reduce_scatter_ragged_candidate_axis():
    """reduce_scatter with C not divisible by W must pad transparently
    (regression: the legacy path silently mis-split the axis)."""
    assert "RAGGED-OK" in _run_snippet(RAGGED_SNIPPET)


# ---------------------------------------------------------------------------
# chaos: worker loss and wire corruption with the sharded wire live
# ---------------------------------------------------------------------------

CHAOS_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults, jax_compat

    ck = sys.argv[1]
    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(graphs, 5, max_size=5)

    def check(res, tag):
        assert [set(l) for l in res.levels] == \\
            [set(l) for l in ref.levels], tag
        for code, s in res.supports.items():
            assert s == ref.frequent[code].support, (tag, code)

    # (1) worker loss mid-level with the sharded wire in flight: the
    # supervisor shrinks to one worker and resumes from checkpoint —
    # where the "sharded" wire degenerates to the dense layout — and
    # the result stays bit-identical
    faults.install(faults.FaultSchedule.parse("worker_loss@3"))
    mesh2 = MiningMesh(jax_compat.make_mesh((2,), ("w",)))
    cfg = MirageConfig(minsup=5, n_partitions=4, max_size=5,
                       reduce="reduce_scatter", sharded_wire=True,
                       checkpoint_dir=ck)
    sup = MiningSupervisor(cfg, SupervisorConfig(sleep_fn=lambda s: None),
                           mesh=mesh2)
    res = sup.mine(graphs)
    assert [e.action for e in sup.events] == ["shrink"], sup.events
    assert res.stats[0].level == 3, [st.level for st in res.stats]
    check(res, "worker-loss")
    faults.clear(); faults.reset_log()

    # (2) a bit-flip on the two-shard wire lands inside one shard; that
    # shard's checksum catches it and a single re-fetch heals the level
    faults.install(faults.FaultSchedule.parse("wire_bitflip@3:bit=19"))
    res = Mirage(MirageConfig(minsup=5, n_partitions=4, max_size=5,
                              reduce="reduce_scatter", sharded_wire=True),
                 mesh2).fit(graphs)
    assert [e["kind"] for e in faults.injection_log()] == ["wire_bitflip"]
    check(res, "bitflip")
    print("CHAOS-OK")
""")


def test_sharded_wire_chaos_two_workers(tmp_path):
    assert "CHAOS-OK" in _run_snippet(CHAOS_SNIPPET, tmp_path / "ck")
