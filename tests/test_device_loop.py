"""Device-resident mining loop (DESIGN.md §13).

Three layers of differential coverage:

 1. the device building blocks against their host oracles —
    ``min_dfs_canonical_array`` vs ``is_canonical``, ``device_candidates``
    vs ``generate_candidates`` (exact order), ``device_schedule`` vs
    ``schedule_candidates``;
 2. ``pipeline="device_loop"`` end-to-end against single_sync and the
    host miner: level ORDER and supports must match bit-for-bit across
    packed x backend x worker count, with early termination, the
    unrolled stepping stone, run-granular M escalation, chunked
    checkpoints + resume, and the bail -> single_sync fallback;
 3. the residency contract itself — during a completed device_loop run
    the host candgen runs exactly once (the budget-sizing call) and the
    per-level dispatcher never runs.
"""
import os
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import candgen, dfscode, mining
from repro.core.candgen import EdgeAlphabet, generate_candidates
from repro.core.graphdb import random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig
from repro.core.supervisor import (DEVICE_LOOP_LADDER, LADDER,
                                   MiningSupervisor, SupervisorConfig,
                                   ladder_for)
from repro.runtime import checkpoint as ckpt
from repro.runtime import faults


@pytest.fixture(scope="module")
def db():
    """18-graph DB with 3 frequent levels at minsup 3 ([12, 16, 2])."""
    return random_db(18, n_vertices=6, extra_edge_prob=0.35,
                     n_vlabels=3, n_elabels=2, seed=42)


@pytest.fixture(scope="module")
def canon(db):
    ref = mine_host(db, 3, max_size=4)
    return sorted((c, i.support) for c, i in ref.frequent.items())


def _mine_dl(db, canon, expect_completed=True, **kw):
    cfg = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                       backend="ref", pipeline="device_loop", **kw)
    m = Mirage(cfg)
    res = m.fit(db)
    assert sorted(res.supports.items()) == canon
    assert m.last_device_loop["completed"] == expect_completed, \
        m.last_device_loop
    return m, res


# ---------------------------------------------------------------------------
# 1. device building blocks vs host oracles
# ---------------------------------------------------------------------------

def test_device_canonicality_matches_host():
    """min_dfs_canonical_array agrees with is_canonical on a code pile
    that includes the NON-canonical children host candgen filters."""
    codes = []
    for seed in range(2):
        graphs = random_db(10, n_vertices=6, extra_edge_prob=0.4,
                           n_vlabels=3, n_elabels=2, seed=seed)
        res = mine_host(graphs, 2, max_size=4)
        alpha = EdgeAlphabet((c[0][2], c[0][3], c[0][4])
                             for c in res.frequent if len(c) == 1)
        for code in res.frequent:
            rmp = dfscode.rightmost_path(code)
            n_v = max(max(e[0], e[1]) for e in code) + 1
            vl = {}
            for (i, j, li, _le, lj) in code:
                vl[i] = li
                vl[j] = lj
            existing = {(min(e[0], e[1]), max(e[0], e[1])) for e in code}
            rmv = rmp[-1]
            for w in rmp[:-1]:
                if (min(rmv, w), max(rmv, w)) in existing:
                    continue
                for (e_lab, other) in alpha.partners(vl[rmv]):
                    if other == vl[w]:
                        codes.append(
                            code + ((rmv, w, vl[rmv], e_lab, vl[w]),))
            for w in rmp:
                for (e_lab, other) in alpha.partners(vl[w]):
                    codes.append(code + ((w, n_v, vl[w], e_lab, other),))
    assert len(codes) > 300
    L = max(len(c) for c in codes)
    arr = np.stack([dfscode.code_to_array(c, L) for c in codes])
    fn = jax.jit(jax.vmap(
        lambda a: dfscode.min_dfs_canonical_array(
            a, n_vertex_slots=L + 1, max_states=64)))
    canon_d, ovf_d = map(np.asarray, fn(jnp.asarray(arr)))
    assert not ovf_d.any()
    host = np.array([dfscode.is_canonical(c) for c in codes])
    mism = np.flatnonzero(host != canon_d.astype(bool))
    assert mism.size == 0, [codes[i] for i in mism[:5]]


def test_device_candgen_matches_host_order():
    """device_candidates reproduces generate_candidates exactly —
    same candidates, same parent/extension metadata, same ORDER."""
    for seed in (42, 43):
        graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                           n_vlabels=3, n_elabels=2, seed=seed)
        res = mine_host(graphs, 5, max_size=4)
        alpha = EdgeAlphabet((c[0][2], c[0][3], c[0][4])
                             for c in res.frequent if len(c) == 1)
        triples = sorted({t for c in alpha.canonical()
                          for t in (c, (c[2], c[1], c[0]))})
        tri_arr = jnp.asarray(np.array(triples, np.int32))
        by_level = {}
        for c in res.frequent:
            by_level.setdefault(len(c), []).append(c)
        checked = 0
        for lvl in sorted(by_level):
            parents = sorted(by_level[lvl])
            host = generate_candidates(parents, alpha)
            L = lvl + 1
            codes = jnp.asarray(np.stack(
                [dfscode.code_to_array(c, L) for c in parents]))
            cb = max(8, 2 * len(host))
            fn = candgen.device_candgen_jit(L, L + 1, 4 * cb, cb, 64)
            meta, ccodes, n_cand, flags = fn(
                codes, jnp.int32(len(parents)), tri_arr)
            assert not np.asarray(flags).any()
            assert int(n_cand) == len(host), (seed, lvl)
            dev = candgen.candidates_from_arrays(
                np.asarray(meta), np.asarray(ccodes), int(n_cand), triples)
            for d, h in zip(dev, host):
                assert d.code == h.code
                assert d.parent == h.parent
                assert d.ext == h.ext
            checked += len(host)
        assert checked > 0


def test_device_schedule_matches_host():
    """device_schedule reproduces schedule_candidates' tiling (meta,
    tiles, inverse map) and flags overflow when rows run out."""
    rng = np.random.default_rng(0)
    for trial in range(10):
        C = int(rng.integers(1, 60))
        T = int(rng.integers(2, 12))
        NP = int(rng.integers(1, 20))
        meta = np.stack([
            rng.integers(0, NP, C), rng.integers(0, 4, C),
            rng.integers(0, 5, C), rng.integers(0, 2, C),
            rng.integers(0, T, C)], axis=1).astype(np.int32)
        meta = meta[np.argsort(meta[:, 0], kind="stable")]
        tc = int(rng.choice([1, 2, 4, 8]))
        host = candgen.schedule_candidates(meta, tc,
                                           max_inflation=float("inf"))
        cb = C + int(rng.integers(0, 16))
        rows = max(host.meta.shape[0], cb) + tc * int(rng.integers(0, 3))
        rows = -(-rows // tc) * tc
        pmeta = np.concatenate(
            [meta,
             np.tile(np.asarray([0, 0, 0, 1, 0], np.int32), (cb - C, 1))])
        sched, tiles, inv, ovf = candgen.device_schedule(
            jnp.asarray(pmeta), jnp.int32(C), tile_c=tc, n_triples=T,
            rows=rows)
        sched, tiles, inv = map(np.asarray, (sched, tiles, inv))
        assert not bool(ovf), trial
        hs = host.meta.shape[0]
        assert np.array_equal(sched[:hs], host.meta), trial
        assert (sched[hs:, 5] == 0).all(), trial
        assert np.array_equal(tiles[:hs // tc], host.tiles), trial
        assert np.array_equal(inv[:C], host.inv), trial
    # 16 singleton parent groups x tile_c=8 cannot fit 16 rows
    meta = np.stack([np.arange(16), *([np.zeros(16, int)] * 3),
                     np.zeros(16, int)], axis=1).astype(np.int32)
    _, _, _, ovf = candgen.device_schedule(
        jnp.asarray(meta), jnp.int32(16), tile_c=8, n_triples=4, rows=16)
    assert bool(ovf)


# ---------------------------------------------------------------------------
# 2. device_loop end-to-end conformance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [False, True])
def test_device_loop_matches_single_sync_and_host(db, canon, packed):
    cfg_ss = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                          backend="ref", packed_support=packed)
    res_ss = Mirage(cfg_ss).fit(db)
    m, res_dl = _mine_dl(db, canon, packed_support=packed)
    # level ORDER, not just set equality — the wire preserves min-dfs order
    assert [list(l) for l in res_dl.levels] == \
        [list(l) for l in res_ss.levels]
    assert sorted(res_ss.supports.items()) == canon
    assert m.last_device_loop["chunks"] == 1
    assert [(s.level, s.n_candidates, s.n_frequent) for s in res_dl.stats] \
        == [(s.level, s.n_candidates, s.n_frequent) for s in res_ss.stats]


def test_device_loop_fused_interpret():
    """The fused kernel path inside the loop body (interpret-mode Pallas
    unrolls the grid at trace time, so: tiny DB)."""
    tiny = random_db(8, n_vertices=4, extra_edge_prob=0.3, n_vlabels=2,
                     n_elabels=1, seed=3)
    ref = mine_host(tiny, 3, max_size=3)
    tcanon = sorted((c, i.support) for c, i in ref.frequent.items())
    cfg = MirageConfig(minsup=3, n_partitions=2, max_size=3,
                       backend="fused_interpret", pipeline="device_loop")
    m = Mirage(cfg)
    res = m.fit(tiny)
    assert m.last_device_loop["completed"], m.last_device_loop
    assert sorted(res.supports.items()) == tcanon


_MULTIWORKER_SNIPPET = textwrap.dedent("""
    import os, sys
    W = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count=%d" % W
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 3, max_size=4)
    canon = sorted((c, i.support) for c, i in ref.frequent.items())
    mesh = MiningMesh(jax_compat.make_mesh((W,), ("w",)))
    cfg = MirageConfig(minsup=3, n_partitions=4, max_size=4,
                       backend="ref", pipeline="device_loop")
    m = Mirage(cfg, mesh)
    res = m.fit(graphs)
    assert m.last_device_loop["completed"], m.last_device_loop
    assert sorted(res.supports.items()) == canon
    print("W-OK")
""")


@pytest.mark.parametrize("workers", [2, 4])
def test_device_loop_multiworker(workers):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run(
        [sys.executable, "-c", _MULTIWORKER_SNIPPET, str(workers)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "W-OK" in out.stdout


def test_device_loop_early_termination(db, canon):
    """max_size far past the fixpoint: the while_loop exits when a level
    yields no survivors; unexecuted slots never reach the decode."""
    cfg = MirageConfig(minsup=3, n_partitions=2, max_size=8,
                       backend="ref", pipeline="device_loop")
    m = Mirage(cfg)
    res = m.fit(db)
    assert m.last_device_loop["completed"]
    assert sorted(res.supports.items()) == canon
    assert [len(l) for l in res.levels] == [12, 16, 2]
    assert res.stats[-1].level == 4, "loop must exit at the fixpoint"


def test_device_loop_unrolled_matches_while(db, canon):
    for unroll in (1, 2):
        _mine_dl(db, canon, device_loop_unroll=unroll)


def test_device_loop_escalation_valve():
    """Run-granular M escalation: overflow at the chunk boundary doubles
    the uniform M and reruns; the result matches the exact host miner."""
    dense = random_db(8, n_vertices=8, extra_edge_prob=0.9, n_vlabels=1,
                      n_elabels=1, seed=7)
    ref = mine_host(dense, 4, max_size=3)
    dcanon = sorted((c, i.support) for c, i in ref.frequent.items())
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=3,
                       backend="ref", pipeline="device_loop",
                       max_embeddings=2, max_embeddings_limit=4096)
    m = Mirage(cfg)
    res = m.fit(dense)
    assert m.last_device_loop["completed"], m.last_device_loop
    assert sorted(res.supports.items()) == dcanon
    assert m.last_device_loop["escalations"] > 0
    assert sum(s.escalations for s in res.stats) > 0
    assert res.total_overflow == 0


def test_device_loop_chunked_checkpoint_resume(db, canon, tmp_path):
    ckdir = str(tmp_path / "dl_ck")
    m, _ = _mine_dl(db, canon, device_loop_ckpt_every=1,
                    checkpoint_dir=ckdir)
    assert m.last_device_loop["chunks"] == 3, m.last_device_loop
    cadence = ckpt.ChunkCadence(1, 4, 1)
    assert m.last_device_loop["chunks"] == cadence.n_chunks
    # lose everything past the level-2 checkpoint, resume mid-run
    steps = ckpt.all_steps(ckdir)
    assert steps, "no checkpoints written"
    for s in steps:
        if s > 2:
            shutil.rmtree(os.path.join(ckdir, f"step_{s:010d}"))
    cfg = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                       backend="ref", pipeline="device_loop",
                       checkpoint_dir=ckdir)
    m2 = Mirage(cfg)
    res2 = m2.fit(db, resume=True)
    assert sorted(res2.supports.items()) == canon
    assert m2.last_device_loop["completed"], m2.last_device_loop


def test_device_loop_tiny_budget_falls_back(db, canon):
    """A hopeless candidate budget bails with a flag; the supervisor-free
    driver falls back to single_sync and the result is still exact."""
    m, _ = _mine_dl(db, canon, expect_completed=False, device_c_budget=8)
    assert m.last_device_loop["fallback"]
    assert "flags" in m.last_device_loop["fallback"]


def test_device_loop_wire_bitflip_refetch(db, canon):
    """A checksum-failing run wire is refetched, and the injected fault
    is consumed exactly once."""
    sched = faults.FaultSchedule.parse("wire_bitflip@4")
    faults.install(sched)
    try:
        m, _ = _mine_dl(db, canon)
        assert all(s._remaining == 0 for s in sched.specs), \
            "wire_bitflip fault never consumed"
    finally:
        faults.clear()


def test_supervisor_degrades_device_loop_to_single_sync(db, canon):
    """The device_loop ladder inserts a single_sync rung before the
    backend/pipeline rungs of the stock ladder."""
    assert ladder_for(MirageConfig(minsup=3, max_size=4,
                                   pipeline="device_loop")) \
        == DEVICE_LOOP_LADDER
    assert ladder_for(MirageConfig(minsup=3)) == LADDER
    sched = faults.FaultSchedule.parse("kernel_fault@2*4")
    faults.install(sched)
    try:
        cfg = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                           backend="ref", pipeline="device_loop")
        sup = MiningSupervisor(cfg, SupervisorConfig(max_retries=8,
                                                     backoff_base=0.0))
        res = sup.mine(db)
        assert sorted(res.supports.items()) == canon
        rungs = [e.detail for e in sup.events if e.action == "degrade"]
        assert any("single_sync" in d for d in rungs), rungs
    finally:
        faults.clear()


def test_candgen_device_stepping_stone(db, canon):
    """candgen="device" swaps the per-level host generator for the
    device kernel inside the host-driven pipelines."""
    for pipeline in ("single_sync", "legacy"):
        cfg = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                           backend="ref", pipeline=pipeline,
                           candgen="device")
        res = Mirage(cfg).fit(db)
        assert sorted(res.supports.items()) == canon, pipeline


# ---------------------------------------------------------------------------
# 3. the residency contract
# ---------------------------------------------------------------------------

def test_no_host_candgen_mid_loop(db, canon, monkeypatch):
    """During a completed device_loop run the host candgen runs exactly
    once (the budget-sizing call on the start level) and the per-level
    dispatcher never runs — there is no host work between levels."""
    calls = []
    real = mining.generate_candidates

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    def boom(*a, **kw):
        raise AssertionError("dispatch_level ran under device_loop")

    monkeypatch.setattr(mining, "generate_candidates", counting)
    monkeypatch.setattr(mining, "dispatch_level", boom)
    m, _ = _mine_dl(db, canon)
    assert len(calls) == 1, f"{len(calls)} host candgen calls"


def test_device_loop_config_validation():
    with pytest.raises(ValueError, match="max_size"):
        MirageConfig(minsup=3, pipeline="device_loop")
    with pytest.raises(ValueError, match="bucket_shapes"):
        MirageConfig(minsup=3, max_size=4, pipeline="device_loop",
                     bucket_shapes=False)
    with pytest.raises(ValueError, match="escalate_on_overflow"):
        MirageConfig(minsup=3, max_size=4, pipeline="device_loop",
                     escalate_on_overflow=False)
    with pytest.raises(ValueError, match="candgen"):
        MirageConfig(minsup=3, candgen="quantum")
    # host speculation is statically impossible under device candgen
    assert not MirageConfig(minsup=3, max_size=4,
                            pipeline="device_loop").overlap_candgen
    assert not MirageConfig(minsup=3, candgen="device").overlap_candgen
    assert MirageConfig(minsup=3).overlap_candgen


def test_chunk_cadence():
    c = ckpt.ChunkCadence(1, 6, 2)
    assert c.boundaries() == [3, 5, 6]
    assert c.n_chunks == 3
    assert c.max_fetches() == 3 + 2 * 2
    whole = ckpt.ChunkCadence(1, 6, None)
    assert whole.boundaries() == [6]
    assert whole.max_fetches() == 1
    assert ckpt.ChunkCadence(3, 4, 1).boundaries() == [4]
