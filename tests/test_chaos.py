"""Chaos differential suite (DESIGN.md §10, ISSUE 6 acceptance):
under every scheduled fault — worker loss at each level, corrupted
latest checkpoint, wire bit-flips, cap-miss storms, in-kernel faults,
and random mixed schedules — mining must COMPLETE and return a frequent
set bit-identical to the fault-free host oracle, with a single worker
loss replaying at most one level from checkpoint.

Faults are injected into the production code paths (driver loop, level
program dispatch, wire fetch, checkpoint save); nothing is mocked."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax._src.array as _jarr
import numpy as np
import pytest

from repro.core.graphdb import random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig
from repro.core.supervisor import MiningSupervisor, SupervisorConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime import faults

# Deterministic 4-level DB with multiple survivors at every level
# (levels: 3, 5, 10, 5 frequent patterns) — deep enough to place faults
# at levels 2..4, wide enough that cap storms force real retries.
MINSUP, MAX_SIZE, NPARTS = 5, 5, 2
DB = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
REF = mine_host(DB, MINSUP, max_size=MAX_SIZE)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    faults.reset_log()
    yield
    faults.clear()
    faults.reset_log()


def _cfg(**kw):
    kw.setdefault("max_size", MAX_SIZE)
    return MirageConfig(minsup=MINSUP, n_partitions=NPARTS, **kw)


def assert_parity(res):
    """The chaos contract: bit-identical to the fault-free host oracle."""
    assert [set(l) for l in res.levels] == [set(l) for l in REF.levels]
    assert len(res.supports) == len(REF.frequent)
    for code, sup in res.supports.items():
        assert sup == REF.frequent[code].support


def _supervised(schedule_text, *, ckpt_dir=None, max_retries=8,
                degrade_after=2, **cfg_kw):
    faults.install(faults.FaultSchedule.parse(schedule_text))
    sup = MiningSupervisor(
        _cfg(checkpoint_dir=ckpt_dir, **cfg_kw),
        SupervisorConfig(max_retries=max_retries,
                         degrade_after=degrade_after,
                         sleep_fn=lambda s: None))
    return sup.mine(DB), sup


# ---------------------------------------------------------------------------
# worker loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", [2, 3, 4])
def test_worker_loss_at_each_level_replays_at_most_one_level(
        tmp_path, level):
    res, sup = _supervised(f"worker_loss@{level}",
                           ckpt_dir=str(tmp_path / "ck"))
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["worker_loss"]
    assert sup.events[0].level == level
    # the successful attempt resumed from the level-(L-1) checkpoint:
    # its first mined level IS the faulted one (levels < L never replay)
    if level > 2:                       # level 2 has no checkpoint yet
        assert res.stats[0].level == level


def test_worker_loss_without_checkpoints_restarts_clean():
    res, sup = _supervised("worker_loss@3")
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["worker_loss"]


# ---------------------------------------------------------------------------
# wire integrity
# ---------------------------------------------------------------------------

def test_wire_bitflip_recovers_via_refetch_in_run():
    """A single flipped bit on the device→host link is caught by the
    checksum and healed by ONE re-fetch — no supervisor involved, and
    clean levels still cost exactly one transfer."""
    faults.install(faults.FaultSchedule.parse("wire_bitflip@3:bit=19"))
    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = Mirage(_cfg()).fit(DB)
    finally:
        _jarr.ArrayImpl._value = orig
    assert_parity(res)
    assert [e["kind"] for e in faults.injection_log()] == ["wire_bitflip"]
    # one extra fetch for the corrupted level, one for every clean level
    assert counts["n"] == len(res.stats) + 1


def test_wire_bitflip_storm_escalates_to_supervisor():
    """Corruption on every fetch attempt exhausts the re-fetch budget,
    surfaces as a transient fault, and the supervisor's retry wins."""
    res, sup = _supervised("wire_bitflip@3*3")
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["transient"]
    assert len(faults.injection_log()) == 3


# ---------------------------------------------------------------------------
# survivor-cap storm
# ---------------------------------------------------------------------------

def test_cap_miss_storm_stays_exact_in_run():
    """A forced cap of 1 at every mid level drives each through the
    materialize-only retry path — supports must not move."""
    faults.install(faults.FaultSchedule.parse(
        "cap_storm@2;cap_storm@3;cap_storm@4"))
    res = Mirage(_cfg()).fit(DB)
    assert_parity(res)
    fired = [e["kind"] for e in faults.injection_log()]
    assert fired == ["cap_storm"] * 3


# ---------------------------------------------------------------------------
# kernel faults → degradation ladder
# ---------------------------------------------------------------------------

def test_kernel_fault_descends_degradation_ladder(tmp_path):
    """Repeated kernel faults walk fused → pallas/interpret → legacy;
    the legacy pipeline dispatches no level program at all, so it is
    immune to the remaining scheduled faults and completes."""
    res, sup = _supervised("kernel_fault@2*6",
                           ckpt_dir=str(tmp_path / "ck"))
    assert_parity(res)
    assert sup.rung == 2
    assert [e.action for e in sup.events] == [
        "retry", "degrade", "retry", "degrade"]
    assert all(e.kind == "kernel" for e in sup.events)


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------

def test_corrupted_latest_checkpoint_falls_back_on_resume(tmp_path):
    root = str(tmp_path / "ck")
    faults.install(faults.FaultSchedule.parse(
        "ckpt_corrupt@3:mode=truncate"))
    Mirage(_cfg(max_size=3, checkpoint_dir=root)).fit(DB)
    faults.clear()
    assert ckpt.all_steps(root) == [2, 3]          # 3 is silently rotten
    res = Mirage(_cfg(checkpoint_dir=root)).fit(DB, resume=True)
    assert_parity(res)
    # the resume skipped + reaped step 3, replayed from the intact step
    # 2 checkpoint, then re-saved levels 3 and 4
    assert res.stats[0].level == 3
    assert ckpt.all_steps(root)[-1] == 4


def test_all_checkpoints_corrupt_restarts_clean(tmp_path):
    root = str(tmp_path / "ck")
    Mirage(_cfg(max_size=3, checkpoint_dir=root)).fit(DB)
    for step in ckpt.all_steps(root):
        faults.damage_checkpoint(
            os.path.join(root, f"step_{step:010d}"), "flip")
    res = Mirage(_cfg(checkpoint_dir=root)).fit(DB, resume=True)
    assert_parity(res)
    assert res.stats[0].level == 2                 # full fresh mine


# ---------------------------------------------------------------------------
# donation re-arming
# ---------------------------------------------------------------------------

def test_donation_rearm_rebuilds_parents_and_stays_exact(
        tmp_path, monkeypatch):
    """With re-arming at k=1, level 3 runs donated despite being
    retryable; the scheduled cap storm forces the retry, the parents are
    gone, and the driver must rebuild them from the level-2 checkpoint
    and replay — ending bit-identical anyway."""
    rebuilds = {"n": 0}
    orig = Mirage._rebuild_parents

    def spying(self, order):
        rebuilds["n"] += 1
        return orig(self, order)

    monkeypatch.setattr(Mirage, "_rebuild_parents", spying)
    faults.install(faults.FaultSchedule.parse("cap_storm@3"))
    res = Mirage(_cfg(checkpoint_dir=str(tmp_path / "ck"),
                      donation_rearm_levels=1)).fit(DB)
    assert_parity(res)
    assert rebuilds["n"] == 1
    assert [e["kind"] for e in faults.injection_log()] == ["cap_storm"]


def test_donation_rearm_disabled_without_checkpoints():
    """No checkpoint_dir → the policy can never arm; a cap storm takes
    the ordinary in-level retry (parents were kept alive)."""
    faults.install(faults.FaultSchedule.parse("cap_storm@3"))
    res = Mirage(_cfg(donation_rearm_levels=1)).fit(DB)
    assert_parity(res)


# ---------------------------------------------------------------------------
# random mixed schedules (fixed-seed CI subset + hypothesis sweep)
# ---------------------------------------------------------------------------

def _mine_under_random_schedule(seed, ckpt_root):
    schedule = faults.FaultSchedule.random(seed, max_level=4, n_faults=2)
    with faults.active(schedule):
        sup = MiningSupervisor(
            _cfg(checkpoint_dir=ckpt_root),
            SupervisorConfig(max_retries=10, degrade_after=2,
                             sleep_fn=lambda s: None))
        res = sup.mine(DB)
    assert_parity(res)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_schedule_fixed_seeds(tmp_path, seed):
    _mine_under_random_schedule(seed, str(tmp_path / "ck"))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_random_schedule_property(seed):
        with tempfile.TemporaryDirectory() as td:
            _mine_under_random_schedule(seed, os.path.join(td, "ck"))


# ---------------------------------------------------------------------------
# multi-worker elastic shrink (subprocess: forces 2 CPU devices)
# ---------------------------------------------------------------------------

SHRINK_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import MirageConfig
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults, jax_compat

    ck = sys.argv[1]
    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(graphs, 5, max_size=5)

    faults.install(faults.FaultSchedule.parse("worker_loss@3"))
    mesh2 = MiningMesh(jax_compat.make_mesh((2,), ("w",)))
    sup = MiningSupervisor(
        MirageConfig(minsup=5, n_partitions=4, max_size=5,
                     checkpoint_dir=ck),
        SupervisorConfig(sleep_fn=lambda s: None),
        mesh=mesh2)
    res = sup.mine(graphs)

    assert [e.action for e in sup.events] == ["shrink"], sup.events
    assert "1 worker" in sup.events[0].detail
    # the shrunken attempt resumed from the level-2 checkpoint: only the
    # faulted level onward replays
    assert res.stats[0].level == 3, [st.level for st in res.stats]
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup_ in res.supports.items():
        assert sup_ == ref.frequent[code].support
    print("SHRINK-OK")
""")


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_worker_loss_on_two_workers_shrinks_to_one(tmp_path):
    assert "SHRINK-OK" in _run_snippet(SHRINK_SNIPPET, tmp_path / "ck")
