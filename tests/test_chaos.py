"""Chaos differential suite (DESIGN.md §10, ISSUE 6 acceptance):
under every scheduled fault — worker loss at each level, corrupted
latest checkpoint, wire bit-flips, cap-miss storms, in-kernel faults,
and random mixed schedules — mining must COMPLETE and return a frequent
set bit-identical to the fault-free host oracle, with a single worker
loss replaying at most one level from checkpoint.

Faults are injected into the production code paths (driver loop, level
program dispatch, wire fetch, checkpoint save); nothing is mocked."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax._src.array as _jarr
import numpy as np
import pytest

from repro.core.graphdb import random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig, PartialResult
from repro.core.supervisor import MiningSupervisor, SupervisorConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime import faults
from repro.runtime.watchdog import Watchdog

# Deterministic 4-level DB with multiple survivors at every level
# (levels: 3, 5, 10, 5 frequent patterns) — deep enough to place faults
# at levels 2..4, wide enough that cap storms force real retries.
MINSUP, MAX_SIZE, NPARTS = 5, 5, 2
DB = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
REF = mine_host(DB, MINSUP, max_size=MAX_SIZE)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    faults.reset_log()
    yield
    faults.clear()
    faults.reset_log()


def _cfg(**kw):
    kw.setdefault("max_size", MAX_SIZE)
    return MirageConfig(minsup=MINSUP, n_partitions=NPARTS, **kw)


def assert_parity(res):
    """The chaos contract: bit-identical to the fault-free host oracle."""
    assert [set(l) for l in res.levels] == [set(l) for l in REF.levels]
    assert len(res.supports) == len(REF.frequent)
    for code, sup in res.supports.items():
        assert sup == REF.frequent[code].support


def assert_verified_prefix(res):
    """The anytime contract (§14): a PartialResult must be a VERIFIED
    prefix of the fault-free host oracle — every level it does report
    is bit-identical, supports included."""
    assert isinstance(res, PartialResult)
    assert not res.complete
    n = len(res.levels)
    assert n <= len(REF.levels)
    assert [set(map(tuple, l)) for l in res.levels] == \
        [set(l) for l in REF.levels[:n]]
    for code, sup_ in res.supports.items():
        assert sup_ == REF.frequent[tuple(code)].support


def _supervised(schedule_text, *, ckpt_dir=None, max_retries=8,
                degrade_after=2, watchdog=None, on_exhausted="raise",
                **cfg_kw):
    faults.install(faults.FaultSchedule.parse(schedule_text))
    sup = MiningSupervisor(
        _cfg(checkpoint_dir=ckpt_dir, **cfg_kw),
        SupervisorConfig(max_retries=max_retries,
                         degrade_after=degrade_after,
                         on_exhausted=on_exhausted,
                         sleep_fn=lambda s: None),
        watchdog=watchdog)
    return sup.mine(DB), sup


# ---------------------------------------------------------------------------
# worker loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("level", [2, 3, 4])
def test_worker_loss_at_each_level_replays_at_most_one_level(
        tmp_path, level):
    res, sup = _supervised(f"worker_loss@{level}",
                           ckpt_dir=str(tmp_path / "ck"))
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["worker_loss"]
    assert sup.events[0].level == level
    # the successful attempt resumed from the level-(L-1) checkpoint:
    # its first mined level IS the faulted one (levels < L never replay)
    if level > 2:                       # level 2 has no checkpoint yet
        assert res.stats[0].level == level


def test_worker_loss_without_checkpoints_restarts_clean():
    res, sup = _supervised("worker_loss@3")
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["worker_loss"]


# ---------------------------------------------------------------------------
# wire integrity
# ---------------------------------------------------------------------------

def test_wire_bitflip_recovers_via_refetch_in_run():
    """A single flipped bit on the device→host link is caught by the
    checksum and healed by ONE re-fetch — no supervisor involved, and
    clean levels still cost exactly one transfer."""
    faults.install(faults.FaultSchedule.parse("wire_bitflip@3:bit=19"))
    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = Mirage(_cfg()).fit(DB)
    finally:
        _jarr.ArrayImpl._value = orig
    assert_parity(res)
    assert [e["kind"] for e in faults.injection_log()] == ["wire_bitflip"]
    # one extra fetch for the corrupted level, one for every clean level
    assert counts["n"] == len(res.stats) + 1


def test_wire_bitflip_storm_escalates_to_supervisor():
    """Corruption on every fetch attempt exhausts the re-fetch budget,
    surfaces as a transient fault, and the supervisor's retry wins."""
    res, sup = _supervised("wire_bitflip@3*3")
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["transient"]
    assert len(faults.injection_log()) == 3


# ---------------------------------------------------------------------------
# survivor-cap storm
# ---------------------------------------------------------------------------

def test_cap_miss_storm_stays_exact_in_run():
    """A forced cap of 1 at every mid level drives each through the
    materialize-only retry path — supports must not move."""
    faults.install(faults.FaultSchedule.parse(
        "cap_storm@2;cap_storm@3;cap_storm@4"))
    res = Mirage(_cfg()).fit(DB)
    assert_parity(res)
    fired = [e["kind"] for e in faults.injection_log()]
    assert fired == ["cap_storm"] * 3


# ---------------------------------------------------------------------------
# kernel faults → degradation ladder
# ---------------------------------------------------------------------------

def test_kernel_fault_descends_degradation_ladder(tmp_path):
    """Repeated kernel faults walk fused → pallas/interpret → legacy;
    the legacy pipeline dispatches no level program at all, so it is
    immune to the remaining scheduled faults and completes."""
    res, sup = _supervised("kernel_fault@2*6",
                           ckpt_dir=str(tmp_path / "ck"))
    assert_parity(res)
    assert sup.rung == 2
    assert [e.action for e in sup.events] == [
        "retry", "degrade", "retry", "degrade"]
    assert all(e.kind == "kernel" for e in sup.events)


# ---------------------------------------------------------------------------
# checkpoint corruption
# ---------------------------------------------------------------------------

def test_corrupted_latest_checkpoint_falls_back_on_resume(tmp_path):
    root = str(tmp_path / "ck")
    faults.install(faults.FaultSchedule.parse(
        "ckpt_corrupt@3:mode=truncate"))
    Mirage(_cfg(max_size=3, checkpoint_dir=root)).fit(DB)
    faults.clear()
    assert ckpt.all_steps(root) == [2, 3]          # 3 is silently rotten
    res = Mirage(_cfg(checkpoint_dir=root)).fit(DB, resume=True)
    assert_parity(res)
    # the resume skipped + reaped step 3, replayed from the intact step
    # 2 checkpoint, then re-saved levels 3 and 4
    assert res.stats[0].level == 3
    assert ckpt.all_steps(root)[-1] == 4


def test_all_checkpoints_corrupt_restarts_clean(tmp_path):
    root = str(tmp_path / "ck")
    Mirage(_cfg(max_size=3, checkpoint_dir=root)).fit(DB)
    for step in ckpt.all_steps(root):
        faults.damage_checkpoint(
            os.path.join(root, f"step_{step:010d}"), "flip")
    res = Mirage(_cfg(checkpoint_dir=root)).fit(DB, resume=True)
    assert_parity(res)
    assert res.stats[0].level == 2                 # full fresh mine


# ---------------------------------------------------------------------------
# donation re-arming
# ---------------------------------------------------------------------------

def test_donation_rearm_rebuilds_parents_and_stays_exact(
        tmp_path, monkeypatch):
    """With re-arming at k=1, level 3 runs donated despite being
    retryable; the scheduled cap storm forces the retry, the parents are
    gone, and the driver must rebuild them from the level-2 checkpoint
    and replay — ending bit-identical anyway."""
    rebuilds = {"n": 0}
    orig = Mirage._rebuild_parents

    def spying(self, order):
        rebuilds["n"] += 1
        return orig(self, order)

    monkeypatch.setattr(Mirage, "_rebuild_parents", spying)
    faults.install(faults.FaultSchedule.parse("cap_storm@3"))
    res = Mirage(_cfg(checkpoint_dir=str(tmp_path / "ck"),
                      donation_rearm_levels=1)).fit(DB)
    assert_parity(res)
    assert rebuilds["n"] == 1
    assert [e["kind"] for e in faults.injection_log()] == ["cap_storm"]


def test_donation_rearm_disabled_without_checkpoints():
    """No checkpoint_dir → the policy can never arm; a cap storm takes
    the ordinary in-level retry (parents were kept alive)."""
    faults.install(faults.FaultSchedule.parse("cap_storm@3"))
    res = Mirage(_cfg(donation_rearm_levels=1)).fit(DB)
    assert_parity(res)


# ---------------------------------------------------------------------------
# random mixed schedules (fixed-seed CI subset + hypothesis sweep)
# ---------------------------------------------------------------------------

def _mine_under_random_schedule(seed, ckpt_root):
    schedule = faults.FaultSchedule.random(seed, max_level=4, n_faults=2)
    with faults.active(schedule):
        sup = MiningSupervisor(
            _cfg(checkpoint_dir=ckpt_root),
            SupervisorConfig(max_retries=10, degrade_after=2,
                             sleep_fn=lambda s: None))
        res = sup.mine(DB)
    assert_parity(res)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_schedule_fixed_seeds(tmp_path, seed):
    _mine_under_random_schedule(seed, str(tmp_path / "ck"))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_random_schedule_property(seed):
        with tempfile.TemporaryDirectory() as td:
            _mine_under_random_schedule(seed, os.path.join(td, "ck"))


# ---------------------------------------------------------------------------
# device_loop fault matrix (§14): the whole-run pipeline under the same
# fault kinds, pinned to the oracle through the device_loop→single_sync
# supervisor rung
# ---------------------------------------------------------------------------

def _dl(**kw):
    kw.setdefault("pipeline", "device_loop")
    kw.setdefault("device_loop_ckpt_every", 1)
    return kw


def test_device_loop_run_wire_bitflip_storm_retries(tmp_path):
    """Corruption on all 3 fetch attempts of one chunk's run wire
    surfaces as a transient fault; the supervisor's retry resumes from
    the chunk-boundary checkpoint and ends bit-identical."""
    res, sup = _supervised("wire_bitflip@3*3",
                           ckpt_dir=str(tmp_path / "ck"), **_dl())
    assert_parity(res)
    assert [e.kind for e in sup.events] == ["transient"]
    assert len(faults.injection_log()) == 3


def test_device_loop_kernel_fault_descends_to_single_sync(tmp_path):
    """Repeated kernel faults inside the run window walk the EXTRA
    device-loop rung first: abandon the whole-run loop for the
    per-level single-sync program."""
    res, sup = _supervised("kernel_fault@3*2",
                           ckpt_dir=str(tmp_path / "ck"), **_dl())
    assert_parity(res)
    assert sup.rung == 1                        # single_sync rung
    assert [(e.kind, e.action) for e in sup.events] == [
        ("kernel", "retry"), ("kernel", "degrade")]
    assert "single_sync" in sup.events[-1].detail


def test_device_loop_stalled_chunk_degrades_to_single_sync(tmp_path):
    """An injected mid-chunk stall trips the armed phase deadline; the
    hang forfeits the whole-run loop for the per-level program, which
    bounds any future stall to one level — and stays exact."""
    res, sup = _supervised(
        "hang@3:secs=999", ckpt_dir=str(tmp_path / "ck"),
        watchdog=Watchdog(phase_default=2.0), **_dl())
    assert_parity(res)
    assert sup.rung >= 1
    assert [(e.kind, e.action) for e in sup.events] == [
        ("hang", "degrade")]
    assert sup.watchdog.trips                   # detection was the trip


def test_single_sync_hang_replays_from_checkpoint(tmp_path):
    """The per-level pipeline heals a stalled dispatch by ordinary
    checkpoint replay — no ladder descent needed."""
    res, sup = _supervised("hang@3:secs=999",
                           ckpt_dir=str(tmp_path / "ck"),
                           watchdog=Watchdog(phase_default=2.0))
    assert_parity(res)
    assert sup.rung == 0
    assert [(e.kind, e.action) for e in sup.events] == [
        ("hang", "retry")]
    # the successful attempt resumed from the level-2 checkpoint
    assert res.stats[0].level == 3


# ---------------------------------------------------------------------------
# anytime partial results (§14): every exhaustion path must terminate
# as a VERIFIED prefix of the oracle
# ---------------------------------------------------------------------------

def test_deadline_cuts_partial_at_newest_audited_checkpoint(tmp_path):
    root = str(tmp_path / "ck")
    Mirage(_cfg(checkpoint_dir=root)).fit(DB)   # audited checkpoints 2..4
    sup = MiningSupervisor(
        _cfg(checkpoint_dir=root),
        SupervisorConfig(on_exhausted="partial", sleep_fn=lambda s: None))
    res = sup.mine(DB, deadline_s=1e-6)
    assert_verified_prefix(res)
    assert res.reason == "deadline" and res.audited
    assert res.last_level == 4 and len(res.levels) == 4
    assert [e.kind for e in sup.events] == ["deadline"]


def test_budget_exhaustion_returns_audited_prefix(tmp_path):
    """A permanent fault at level 4 burns the whole retry budget; the
    partial cut lands on the level-3 checkpoint — a 3-level verified
    prefix, not an exception."""
    res, sup = _supervised("worker_loss@4*99",
                           ckpt_dir=str(tmp_path / "ck"),
                           max_retries=2, on_exhausted="partial")
    assert_verified_prefix(res)
    assert res.reason == "budget-exhausted" and res.audited
    assert res.last_level == 3 and len(res.levels) == 3
    assert sup.events[-1].action == "partial"
    assert res.events                           # the event trail rides along


def test_budget_exhaustion_without_checkpoints_is_empty_prefix():
    """No checkpoints to cut at → the (trivially valid) empty prefix,
    clearly marked unaudited."""
    res, _ = _supervised("worker_loss@2*99", max_retries=1,
                         on_exhausted="partial")
    assert_verified_prefix(res)
    assert res.levels == [] and res.last_level == 0
    assert not res.audited


def test_deadline_exhaustion_raises_by_default(tmp_path):
    root = str(tmp_path / "ck")
    Mirage(_cfg(checkpoint_dir=root)).fit(DB)
    sup = MiningSupervisor(_cfg(checkpoint_dir=root),
                           SupervisorConfig(sleep_fn=lambda s: None))
    with pytest.raises(faults.DeadlineExceeded):
        sup.mine(DB, deadline_s=1e-6)


# ---------------------------------------------------------------------------
# multi-worker elastic shrink (subprocess: forces 2 CPU devices)
# ---------------------------------------------------------------------------

SHRINK_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import MirageConfig
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults, jax_compat

    ck = sys.argv[1]
    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(graphs, 5, max_size=5)

    faults.install(faults.FaultSchedule.parse("worker_loss@3"))
    mesh2 = MiningMesh(jax_compat.make_mesh((2,), ("w",)))
    sup = MiningSupervisor(
        MirageConfig(minsup=5, n_partitions=4, max_size=5,
                     checkpoint_dir=ck),
        SupervisorConfig(sleep_fn=lambda s: None),
        mesh=mesh2)
    res = sup.mine(graphs)

    assert [e.action for e in sup.events] == ["shrink"], sup.events
    assert "1 worker" in sup.events[0].detail
    # the shrunken attempt resumed from the level-2 checkpoint: only the
    # faulted level onward replays
    assert res.stats[0].level == 3, [st.level for st in res.stats]
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup_ in res.supports.items():
        assert sup_ == ref.frequent[code].support
    print("SHRINK-OK")
""")


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_worker_loss_on_two_workers_shrinks_to_one(tmp_path):
    assert "SHRINK-OK" in _run_snippet(SHRINK_SNIPPET, tmp_path / "ck")


DL_SHRINK_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import MirageConfig
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults, jax_compat

    ck = sys.argv[1]
    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(graphs, 5, max_size=5)

    faults.install(faults.FaultSchedule.parse("worker_loss@3"))
    mesh2 = MiningMesh(jax_compat.make_mesh((2,), ("w",)))
    sup = MiningSupervisor(
        MirageConfig(minsup=5, n_partitions=4, max_size=5,
                     pipeline="device_loop", device_loop_ckpt_every=1,
                     checkpoint_dir=ck),
        SupervisorConfig(sleep_fn=lambda s: None),
        mesh=mesh2)
    res = sup.mine(graphs)

    assert [e.action for e in sup.events] == ["shrink"], sup.events
    assert "1 worker" in sup.events[0].detail
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup_ in res.supports.items():
        assert sup_ == ref.frequent[code].support
    print("DL-SHRINK-OK")
""")


def test_device_loop_worker_loss_on_two_workers_shrinks(tmp_path):
    """The whole-run pipeline under worker loss at W=2: the supervisor
    shrinks the mesh and the resumed device loop still matches the
    oracle bit for bit."""
    assert "DL-SHRINK-OK" in _run_snippet(DL_SHRINK_SNIPPET,
                                          tmp_path / "ck")
