"""Unit tests for the chaos layer's parts in isolation (DESIGN.md §10):
fault schedules, the wire checksum, checkpoint integrity + fallback,
graph-DB validation, the donation re-arming state machine, and the
supervisor's classifier/shrink policy.  End-to-end fault recovery lives
in test_chaos.py."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import supervisor as sup_mod
from repro.core.graphdb import (Graph, GraphValidationError, paper_toy_db,
                                validate_db)
from repro.core.level_step import wire_checksum
from repro.core.mining import DonationPolicy
from repro.core.partition import make_partitions
from repro.runtime import checkpoint as ckpt
from repro.runtime import faults


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    faults.reset_log()
    yield
    faults.clear()
    faults.reset_log()


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_fault_spec_parse_grammar():
    s = faults.FaultSpec.parse("kernel_fault@3*4")
    assert (s.kind, s.level, s.times) == ("kernel_fault", 3, 4)
    s = faults.FaultSpec.parse("wire_bitflip@2:word=5,bit=12")
    assert (s.level, s.word, s.bit) == (2, 5, 12)
    s = faults.FaultSpec.parse("ckpt_corrupt@2:mode=truncate")
    assert s.mode == "truncate"
    with pytest.raises(ValueError):
        faults.FaultSpec.parse("worker_loss")           # no @level
    with pytest.raises(ValueError):
        faults.FaultSpec.parse("frobnicate@2")          # unknown kind
    with pytest.raises(ValueError):
        faults.FaultSpec.parse("worker_loss@2:color=3")  # unknown option
    with pytest.raises(ValueError):
        faults.FaultSpec.parse("ckpt_corrupt@2:mode=nope")


def test_fault_schedule_parse_and_describe():
    sched = faults.FaultSchedule.parse(
        "worker_loss@2; kernel_fault@3*2 ;wire_bitflip@4:bit=3")
    assert [s.kind for s in sched.specs] == [
        "worker_loss", "kernel_fault", "wire_bitflip"]
    assert "kernel_fault@3*2" in sched.describe()


def test_random_schedule_is_seed_deterministic():
    a = faults.FaultSchedule.random(123, max_level=5, n_faults=3)
    b = faults.FaultSchedule.random(123, max_level=5, n_faults=3)
    assert [vars(s) for s in a.specs] == [vars(s) for s in b.specs]
    c = faults.FaultSchedule.random(124, max_level=5, n_faults=3)
    assert [vars(s) for s in a.specs] != [vars(s) for s in c.specs]
    for s in a.specs:
        assert s.kind in faults.KINDS and s.level >= 2


def test_schedule_fires_exactly_times_and_logs():
    with faults.active(faults.FaultSchedule.parse("worker_loss@2*2")):
        for _ in range(2):
            with pytest.raises(faults.WorkerLost):
                faults.maybe_raise("level_start", 2)
        faults.maybe_raise("level_start", 2)            # budget exhausted
        faults.maybe_raise("level_start", 3)            # wrong level
    log = faults.injection_log()
    assert [(e["kind"], e["level"]) for e in log] == [
        ("worker_loss", 2), ("worker_loss", 2)]
    # re-install re-arms the budgets
    sched = faults.FaultSchedule.parse("worker_loss@2")
    with faults.active(sched):
        with pytest.raises(faults.WorkerLost):
            faults.maybe_raise("level_start", 2)
    with faults.active(sched):
        with pytest.raises(faults.WorkerLost):
            faults.maybe_raise("level_start", 2)


def test_hooks_are_noops_without_schedule():
    faults.maybe_raise("level_start", 2)
    faults.maybe_raise("kernel", 2)
    w = np.arange(8, dtype=np.int32)
    assert faults.corrupt_wire(w, 2) is w
    assert faults.override_cap(17, 2) == 17
    assert faults.injection_log() == []


# ---------------------------------------------------------------------------
# wire checksum
# ---------------------------------------------------------------------------

def test_wire_checksum_host_device_agree():
    body = np.arange(-7, 50, dtype=np.int32) * 92821
    assert int(wire_checksum(body)) == int(wire_checksum(jnp.asarray(body)))
    v = int(wire_checksum(body))
    assert -2**31 <= v < 2**31


def test_wire_checksum_detects_flips_and_swaps():
    body = np.arange(64, dtype=np.int32)
    ref = int(wire_checksum(body))
    for word, bit in [(0, 0), (31, 7), (63, 30)]:
        bad = body.copy()
        bad[word] ^= np.int32(1 << bit)
        assert int(wire_checksum(bad)) != ref
    swapped = body.copy()
    swapped[[3, 40]] = swapped[[40, 3]]
    assert int(wire_checksum(swapped)) != ref


def test_corrupt_wire_flips_scheduled_bit_in_a_copy():
    wire = np.zeros(16, np.int32)
    with faults.active(faults.FaultSchedule.parse(
            "wire_bitflip@2:word=5,bit=3")):
        out = faults.corrupt_wire(wire, 2)
    assert out is not wire and wire[5] == 0
    assert out[5] == 1 << 3 and (np.delete(out, 5) == 0).all()
    # word out of range falls back to the middle word
    with faults.active(faults.FaultSchedule.parse(
            "wire_bitflip@2:word=99")):
        out = faults.corrupt_wire(wire, 2)
    assert out[8] != 0


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

def _tree():
    return {"a": np.arange(6, dtype=np.int32).reshape(2, 3),
            "b": [np.ones(4, np.float32), 7],
            "c": "label"}


def test_checkpoint_roundtrip_with_digests(tmp_path):
    p = str(tmp_path / "ck")
    ckpt.save_pytree(p, _tree(), metadata={"x": 1})
    with open(os.path.join(p, "manifest.json")) as f:
        man = json.load(f)
    assert len(man["digests"]) == man["n_leaves"] == 2
    tree, meta = ckpt.load_pytree(p)
    np.testing.assert_array_equal(tree["a"], _tree()["a"])
    assert meta == {"x": 1}


@pytest.mark.parametrize("mode", ["flip", "truncate", "manifest"])
def test_damaged_checkpoint_raises_integrity_error(tmp_path, mode):
    p = str(tmp_path / "ck")
    ckpt.save_pytree(p, _tree())
    faults.damage_checkpoint(p, mode)
    with pytest.raises(ckpt.CheckpointIntegrityError):
        ckpt.load_pytree(p)


def test_load_step_falls_back_to_newest_intact_and_reaps(tmp_path):
    root = str(tmp_path)
    for step in (1, 2, 3):
        ckpt.save_step(root, step, {"v": np.full(3, step)})
    faults.damage_checkpoint(os.path.join(root, "step_0000000003"), "flip")
    tree, meta = ckpt.load_step(root)
    assert meta["step"] == 2 and tree["v"][0] == 2
    assert ckpt.all_steps(root) == [1, 2]        # corrupt step reaped
    # explicit step stays strict
    faults.damage_checkpoint(os.path.join(root, "step_0000000002"),
                             "truncate")
    with pytest.raises(ckpt.CheckpointIntegrityError):
        ckpt.load_step(root, 2)


def test_load_step_raises_when_everything_is_corrupt(tmp_path):
    root = str(tmp_path)
    ckpt.save_step(root, 1, {"v": np.zeros(2)})
    faults.damage_checkpoint(os.path.join(root, "step_0000000001"),
                             "manifest")
    with pytest.raises(FileNotFoundError):
        ckpt.load_step(root)
    assert ckpt.latest_step(root) is None


def test_latest_step_reaps_tmp_dirs_and_incomplete_steps(tmp_path):
    root = str(tmp_path)
    ckpt.save_step(root, 4, {"v": np.zeros(2)})
    os.makedirs(os.path.join(root, ".tmp.ckpt.dead-writer"))
    incomplete = os.path.join(root, "step_0000000005")
    os.makedirs(incomplete)                      # no manifest/payload
    assert ckpt.latest_step(root) == 4
    assert not os.path.exists(incomplete)
    assert not any(n.startswith(".tmp.") for n in os.listdir(root))


def test_scheduled_ckpt_corruption_hits_matching_step_only(tmp_path):
    root = str(tmp_path)
    with faults.active(faults.FaultSchedule.parse(
            "ckpt_corrupt@2:mode=flip")):
        ckpt.save_step(root, 1, {"v": np.zeros(2)})
        ckpt.save_step(root, 2, {"v": np.ones(2)})
    ckpt.load_step(root, 1)
    with pytest.raises(ckpt.CheckpointIntegrityError):
        ckpt.load_step(root, 2)


# ---------------------------------------------------------------------------
# graph-DB validation
# ---------------------------------------------------------------------------

def _g(vl, e, el):
    return Graph(np.asarray(vl), np.asarray(e).reshape(-1, 2),
                 np.asarray(el))


def test_validate_db_accepts_real_databases():
    validate_db(paper_toy_db())


@pytest.mark.parametrize("bad,msg", [
    (_g([], np.empty((0, 2)), []), "no vertices"),
    (_g([0, 1], [(0, 2)], [0]), "dangling"),
    (_g([0, 1], [(0, -1)], [0]), "dangling"),
    (_g([0, -2], [(0, 1)], [0]), "negative vertex label"),
    (_g([0, 1], [(0, 1)], [-1]), "negative edge label"),
    (_g([0, 1], [(0, 1)], [0, 0]), "edge labels"),
    (_g([0, 1], [(0, 0)], [0]), "self-loop"),
    (_g([0, 1, 2], [(0, 1), (1, 0)], [0, 0]), "duplicate"),
])
def test_validate_db_rejects_malformed_graphs(bad, msg):
    with pytest.raises(GraphValidationError, match=msg):
        validate_db([paper_toy_db()[0], bad])


def test_validate_db_rejects_empty_database():
    with pytest.raises(GraphValidationError, match="empty database"):
        validate_db([])


def test_make_partitions_validates_at_the_load_boundary():
    graphs = paper_toy_db() + [_g([0, 1], [(0, 5)], [0])]
    with pytest.raises(GraphValidationError, match="graph 3"):
        make_partitions(graphs, 2, 2)
    # filtering that empties graphs internally stays legal: minsup high
    # enough that every edge is dropped must NOT raise
    make_partitions(paper_toy_db(), 3, 1)


# ---------------------------------------------------------------------------
# donation re-arming state machine
# ---------------------------------------------------------------------------

def test_donation_policy_arms_after_k_clean_levels():
    pol = DonationPolicy(3, can_rebuild=False)
    for _ in range(5):
        pol.record(retried=False)
    assert not pol.armed                 # no checkpoint -> never arms
    pol.can_rebuild = True
    assert pol.armed
    pol.record(retried=True)             # a retry resets the streak
    assert not pol.armed
    pol.record(False), pol.record(False)
    assert not pol.armed                 # 2 < k
    pol.record(False)
    assert pol.armed


def test_donation_policy_rebuild_resets_streak():
    pol = DonationPolicy(1, can_rebuild=True)
    pol.record(False)
    assert pol.armed
    pol.record_rebuild()
    assert pol.rebuilds == 1 and not pol.armed
    pol.record(False)
    assert pol.armed


def test_donation_policy_zero_k_never_arms():
    pol = DonationPolicy(0, can_rebuild=True)
    for _ in range(10):
        pol.record(False)
    assert not pol.armed


# ---------------------------------------------------------------------------
# supervisor policy units
# ---------------------------------------------------------------------------

def test_classify_maps_taxonomy_to_recovery_classes():
    assert sup_mod.classify(faults.WorkerLost(2, 1)) == "worker_loss"
    assert sup_mod.classify(faults.KernelFault(3)) == "kernel"
    assert sup_mod.classify(faults.WireIntegrityError("x")) == "transient"
    assert sup_mod.classify(faults.CheckpointIntegrityError("x")) == "state"
    assert sup_mod.classify(faults.HangTimeout(3, 0.5)) == "hang"
    assert sup_mod.classify(faults.AuditError(2, "bad word")) == "state"
    assert sup_mod.classify(ValueError("real bug")) is None


def test_elastic_shrink_picks_largest_divisor():
    assert sup_mod.elastic_shrink(4, 12) == 3
    assert sup_mod.elastic_shrink(4, 8) == 2
    assert sup_mod.elastic_shrink(2, 8) == 1
    assert sup_mod.elastic_shrink(1, 8) is None           # nothing below 1
    assert sup_mod.elastic_shrink(4, 8, min_workers=3) is None
    assert sup_mod.elastic_shrink(8, 7) == 7              # 7 | 7


def test_supervisor_reraises_fatal_and_exhausted_budget(tmp_path):
    from repro.core.mining import MirageConfig
    log = tmp_path / "faults.json"
    sup = sup_mod.MiningSupervisor(
        MirageConfig(minsup=2, n_partitions=2, max_size=3),
        sup_mod.SupervisorConfig(max_retries=2, sleep_fn=lambda s: None,
                                 fault_log_path=str(log)))
    # unclassified exceptions are fatal: surface immediately, once
    with faults.active(faults.FaultSchedule.parse("worker_loss@2*99")):
        with pytest.raises(faults.WorkerLost):
            sup.mine(paper_toy_db())
    assert [e.action for e in sup.events][-1] == "give_up"
    assert len([e for e in sup.events if e.action != "give_up"]) == 2
    # crash-safe JSONL: one line per event the moment it happened,
    # plus the end-of-run summary line
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    events = [l for l in lines if "summary" not in l]
    assert len(events) == len(sup.events)
    assert lines[-1]["summary"]["outcome"] == "exhausted"
    assert lines[-1]["summary"]["by_kind"] == {"worker_loss": 2}


def test_supervisor_passes_fatal_through():
    from repro.core.mining import MirageConfig
    sup = sup_mod.MiningSupervisor(
        MirageConfig(minsup=2, n_partitions=2, max_size=3),
        sup_mod.SupervisorConfig(sleep_fn=lambda s: None))
    bad_db = paper_toy_db() + [_g([0, 1], [(0, 7)], [0])]
    with pytest.raises(GraphValidationError, match="dangling"):
        sup.mine(bad_db)             # a real input bug is NOT retried
    assert [e.kind for e in sup.events] == ["fatal"]
