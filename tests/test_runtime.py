"""Runtime substrate: checkpoint atomicity/elasticity, sharding rules,
HLO cost parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.runtime import checkpoint as ckpt
from repro.runtime.sharding import shard_hint, active_mesh


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3),
            "b": [np.float32(1.5), {"c": np.ones((4,), np.int8)}],
            "scalars": {"x": 3, "y": "name", "z": None, "w": True},
            "tup": (np.zeros(2), np.ones(3))}
    p = str(tmp_path / "ck")
    ckpt.save_pytree(p, tree, metadata={"note": "hi"})
    got, meta = ckpt.load_pytree(p)
    assert meta["note"] == "hi"
    assert np.array_equal(got["a"], tree["a"])
    assert got["scalars"] == {"x": 3, "y": "name", "z": None, "w": True}
    assert isinstance(got["tup"], tuple)


def test_checkpoint_steps_retention(tmp_path):
    root = str(tmp_path / "steps")
    for s in (1, 2, 3, 4, 5):
        ckpt.save_step(root, s, {"v": np.full(3, s)}, keep=2)
    assert ckpt.all_steps(root) == [4, 5]
    tree, meta = ckpt.load_step(root)
    assert meta["step"] == 5
    assert tree["v"][0] == 5


def test_checkpoint_atomic_overwrite(tmp_path):
    p = str(tmp_path / "ck")
    ckpt.save_pytree(p, {"v": np.zeros(3)})
    ckpt.save_pytree(p, {"v": np.ones(3)})
    got, _ = ckpt.load_pytree(p)
    assert got["v"][0] == 1.0


def test_shard_hint_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = shard_hint(x, "dp", "model")
    assert y.shape == x.shape


def test_param_specs_cover_rules():
    """Every full-config arch must get model-axis sharding on its big
    matrices under the production-mesh rules (checked symbolically)."""
    from repro.launch.mesh import make_production_mesh  # noqa: F401
    from repro.models.registry import ARCHS, get_config
    from repro.runtime.sharding import param_specs
    import jax.sharding as shd

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    from repro.launch.specs import params_specs as psds
    for arch in ARCHS:
        cfg = get_config(arch)
        sds = psds(cfg)
        specs = param_specs(sds, FakeMesh())
        flat = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, shd.PartitionSpec))
        n_model = sum(1 for s in flat
                      if any("model" in str(p) for p in s if p))
        n_any = sum(1 for s in flat if any(p is not None for p in s))
        # every arch fsdp-shards broadly; archs whose head count divides
        # the 16-way model axis also TP-shard attention (awkward-H archs
        # deliberately keep attention model-replicated — §Perf P3/P12)
        assert n_any >= 5, f"{arch}: too few sharded params"
        assert n_model >= 1, f"{arch}: vocab/ffn must be model-sharded"
        if cfg.n_heads % 16 == 0 and cfg.n_kv % 16 == 0:
            assert n_model >= 3, f"{arch}: divisible heads must TP-shard"


def test_hlo_parser_scan_and_collectives():
    from repro.roofline.hlo import parse_hlo_cost

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))
    c = parse_hlo_cost(jax.jit(f).lower(x, w).compile().as_text())
    assert c.flops == 7 * 2 * 64 * 128 * 128
    assert c.unknown_trip_whiles == 0


def test_hlo_parser_counts_fused_dots():
    from repro.roofline.hlo import parse_hlo_cost

    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    x, w1, w2 = (jnp.ones((32, 64)), jnp.ones((64, 96)), jnp.ones((96, 16)))
    c = parse_hlo_cost(jax.jit(f).lower(x, w1, w2).compile().as_text())
    assert c.flops == 2 * 32 * 64 * 96 + 2 * 32 * 96 * 16
