"""Fused single-launch map phase: scheduling round-trip + parity sweeps
(fused vs ref vs legacy two-launch interpret) + end-to-end mining."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.candgen import schedule_candidates
from repro.core.graphdb import paper_toy_db, random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig
from repro.kernels.ops import fused_level_supports, level_supports


def _random_level(rng, C=5, P=3, G=16, M=8, K=3, T=4, F=6):
    """Random-but-consistent join inputs (ids in [0, 32), PAD=-1)."""
    pol = rng.integers(0, 32, (P, G, M, K)).astype(np.int32)
    pmask = (rng.random((P, G, M)) < 0.7)
    kill = rng.random((P, G, M, K)) < 0.15
    pol = np.where(kill, -1, pol)
    src = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    dst = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    emask = (rng.random((T, G, F)) < 0.7)
    src = np.where(emask, src, -1)
    dst = np.where(emask, dst, -1)
    meta = np.stack([
        rng.integers(0, P, C),
        rng.integers(0, K, C),
        rng.integers(0, K, C),
        rng.integers(0, 2, C),
        rng.integers(0, T, C),
    ], axis=1).astype(np.int32)
    return meta, pol, pmask, src, dst, emask


# ---------------------------------------------------------------------------
# schedule_candidates
# ---------------------------------------------------------------------------

def test_schedule_blocks_are_uniform_and_tile_aligned():
    rng = np.random.default_rng(7)
    meta, *_ = _random_level(rng, C=23, P=4, T=3)
    sched = schedule_candidates(meta, tile_c=4)
    tc = sched.tile_c
    assert 1 <= tc <= 4
    assert sched.meta.shape[0] == sched.n_tiles * tc
    for t in range(sched.n_tiles):
        block = sched.meta[t * tc:(t + 1) * tc]
        assert (block[:, 0] == sched.tiles[t, 0]).all()   # one parent/block
        assert (block[:, 4] == sched.tiles[t, 1]).all()   # one triple/block
    # every canonical candidate appears exactly once, metadata intact
    valid_rows = np.flatnonzero(sched.meta[:, 5])
    assert len(valid_rows) == meta.shape[0]
    assert sorted(sched.inv.tolist()) == sorted(valid_rows.tolist())


def test_schedule_adapts_tile_to_grouping():
    """Scattered (parent, triple) pairs must not inflate the schedule;
    heavily shared pairs must keep wide tiles."""
    # 16 all-distinct pairs -> singleton groups -> tile_c collapses to 1
    scattered = np.zeros((16, 5), np.int32)
    scattered[:, 0] = np.arange(16)          # distinct parents
    s = schedule_candidates(scattered, tile_c=8)
    assert s.tile_c == 1
    assert s.meta.shape[0] == 16             # zero padding
    # 2 groups of 8 -> tile_c stays 8, two blocks
    grouped = np.zeros((16, 5), np.int32)
    grouped[8:, 0] = 1
    g = schedule_candidates(grouped, tile_c=8)
    assert g.tile_c == 8
    assert g.n_tiles == 2


def test_schedule_permutation_round_trip():
    """Gathering scheduled rows with inv must reproduce canonical meta."""
    rng = np.random.default_rng(13)
    meta, *_ = _random_level(rng, C=17, P=5, T=4)
    sched = schedule_candidates(meta, tile_c=8)
    np.testing.assert_array_equal(sched.meta[sched.inv, :5], meta)
    assert (sched.meta[sched.inv, 5] == 1).all()


def test_schedule_groups_duplicate_parents():
    """Candidates sharing (parent, triple) must land in shared blocks."""
    meta = np.asarray([[1, 0, 1, 1, 2]] * 5 + [[0, 0, 1, 1, 0]] * 3,
                      np.int32)
    sched = schedule_candidates(meta, tile_c=4)
    # group (1,2): 5 cands -> 2 tiles; group (0,0): 3 cands -> 1 tile
    assert sched.n_tiles == 3
    counts = {(int(p), int(t)): 0 for p, t in sched.tiles}
    for p, t in sched.tiles:
        counts[(int(p), int(t))] += 1
    assert counts == {(1, 2): 2, (0, 0): 1}


def test_schedule_empty():
    sched = schedule_candidates(np.zeros((0, 5), np.int32), tile_c=4)
    assert sched.meta.shape == (4, 6)
    assert (sched.meta[:, 5] == 0).all()
    assert sched.inv.shape == (0,)


# ---------------------------------------------------------------------------
# kernel parity: fused vs ref vs legacy two-launch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,tc,tg", [
    # C not divisible by tile_c
    (dict(C=7, P=3, G=16, M=8, K=4, T=4, F=8), 4, 8),
    # G not divisible by tile_g (ops pads the graph axis)
    (dict(C=8, P=2, G=12, M=4, K=3, T=3, F=5), 4, 8),
    # both misaligned + non-pow2 everything
    (dict(C=9, P=4, G=24, M=5, K=3, T=5, F=7), 8, 16),
    # single candidate, single graph tile
    (dict(C=1, P=2, G=8, M=4, K=2, T=2, F=4), 8, 8),
])
def test_fused_matches_ref_and_two_launch(shape, tc, tg):
    rng = np.random.default_rng(100 + shape["G"])
    meta, pol, pmask, src, dst, emask = _random_level(rng, **shape)
    args = tuple(map(jnp.asarray, (meta, pol, pmask, src, dst, emask)))
    s_ref, e_ref = level_supports(*args, backend="ref")
    s_two, e_two = level_supports(*args, backend="interpret",
                                  tile_g=tg, tile_c=tc)
    s_f, e_f = level_supports(*args, backend="fused_interpret",
                              tile_g=tg, tile_c=tc)
    assert_allclose(np.asarray(s_f), np.asarray(s_ref))
    assert_allclose(np.asarray(e_f), np.asarray(e_ref))
    assert_allclose(np.asarray(s_f), np.asarray(s_two))
    assert_allclose(np.asarray(e_f), np.asarray(e_two))


def test_fused_duplicate_parent_batches():
    """Many candidates sharing one (parent, triple) — the case the
    parent-grouped schedule optimizes — must stay exact."""
    rng = np.random.default_rng(3)
    meta, pol, pmask, src, dst, emask = _random_level(
        rng, C=12, P=3, G=16, M=6, K=3, T=3, F=6)
    meta[:, 0] = np.asarray([1] * 9 + [2] * 3)   # heavy parent skew
    meta[:, 4] = np.asarray([0] * 6 + [2] * 6)
    args = tuple(map(jnp.asarray, (meta, pol, pmask, src, dst, emask)))
    s_ref, e_ref = level_supports(*args, backend="ref")
    s_f, e_f = level_supports(*args, backend="fused_interpret",
                              tile_g=8, tile_c=4)
    assert_allclose(np.asarray(s_f), np.asarray(s_ref))
    assert_allclose(np.asarray(e_f), np.asarray(e_ref))


def test_fused_multi_partition_stacks():
    """The (PP, ...) single-launch covers all partitions — must equal
    per-partition ref results stacked."""
    rng = np.random.default_rng(17)
    meta, pol, pmask, src, dst, emask = _random_level(
        rng, C=6, P=3, G=8, M=4, K=3, T=3, F=5)
    pol2 = np.stack([pol, np.roll(pol, 1, axis=1)])        # (2, P, G, M, K)
    pmask2 = np.stack([pmask, np.roll(pmask, 1, axis=1)])
    src2, dst2, emask2 = (np.stack([a, a]) for a in (src, dst, emask))

    sched = schedule_candidates(meta, tile_c=4)
    sup, emb = fused_level_supports(
        jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
        jnp.asarray(pol2), jnp.asarray(pmask2), jnp.asarray(src2),
        jnp.asarray(dst2), jnp.asarray(emask2), tile_g=8, interpret=True)
    sup = np.asarray(sup)[:, sched.inv]                    # canonical order
    emb = np.asarray(emb)[:, sched.inv]
    for pp in range(2):
        s_ref, e_ref = level_supports(
            jnp.asarray(meta), jnp.asarray(pol2[pp]), jnp.asarray(pmask2[pp]),
            jnp.asarray(src2[pp]), jnp.asarray(dst2[pp]),
            jnp.asarray(emask2[pp]), backend="ref")
        assert_allclose(sup[pp], np.asarray(s_ref))
        assert_allclose(emb[pp], np.asarray(e_ref))


# ---------------------------------------------------------------------------
# end-to-end: fused backend through the distributed driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reduce", ["psum", "reduce_scatter"])
def test_mirage_fused_backend_toy_db(reduce):
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    cfg = MirageConfig(minsup=2, n_partitions=2, max_embeddings=8,
                       backend="fused_interpret", reduce=reduce)
    res = Mirage(cfg).fit(graphs)
    assert sum(res.counts()) == 13
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code


def test_mirage_fused_backend_random_db():
    graphs = random_db(24, n_vertices=7, extra_edge_prob=0.3, n_vlabels=3,
                       n_elabels=2, seed=11)
    ref = mine_host(graphs, 5, max_size=4)
    res = Mirage(MirageConfig(minsup=5, n_partitions=4, max_size=4,
                              backend="fused_interpret")).fit(graphs)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code
