"""Continuous invariant auditor units (DESIGN.md §14).

Doctored-level tests: hand a consistent level to the auditor and flip
exactly one invariant at a time — support threshold, support range,
downward closure, monotonicity, canonicality — pinning both that the
violation raises :class:`AuditError` and that the clean level appends
a report row.  The overhead model is gated here at the same <5% bound
``benchmarks/check_recovery.py`` enforces in CI.
"""
import numpy as np
import pytest

from repro.core import dfscode
from repro.core.auditor import (Auditor, audit_frequent_set,
                                audit_overhead_model, describe_audit_word)
from repro.core.candgen import Candidate
from repro.core.graphdb import random_db
from repro.core.host_miner import mine_host
from repro.runtime.faults import AuditError

# a canonical 2-edge code and its 1-edge parent
PARENT = ((0, 1, 0, 0, 0),)
CHILD = ((0, 1, 0, 0, 0), (1, 2, 0, 0, 1))
# same shape, labels permuted so the min DFS code starts elsewhere
NON_CANON = ((0, 1, 1, 0, 0), (1, 2, 0, 0, 0))
NC_PARENT = ((0, 1, 1, 0, 0),)


# ---------------------------------------------------------------------------
# audit word
# ---------------------------------------------------------------------------

def test_describe_audit_word():
    assert describe_audit_word(0) == "clean"
    assert describe_audit_word(1) == "monotonicity"
    assert describe_audit_word(3) == "monotonicity+compaction"
    assert describe_audit_word(15) == \
        "monotonicity+compaction+support-range+survivor-count"


def test_check_wire_zero_is_clean_nonzero_raises():
    a = Auditor(minsup=5)
    a.check_wire(3, 0)                          # no raise, no report row
    assert a.report == []
    with pytest.raises(AuditError) as ei:
        a.check_wire(3, 0x5)
    assert ei.value.level == 3
    assert "monotonicity" in str(ei.value) and "range" in str(ei.value)


# ---------------------------------------------------------------------------
# per-level spot checks (doctored levels)
# ---------------------------------------------------------------------------

def _level(gsup_val=6, code=CHILD, parent_idx=0, parents=(PARENT,),
           parent_sup=8):
    cands = [Candidate(code, parent_idx, None)]
    keep = np.array([0])
    gsup = np.array([gsup_val])
    supports = {p: parent_sup for p in parents}
    return dict(cands=cands, keep=keep, gsup=gsup,
                parents=list(parents), supports=supports)


def test_check_level_clean_appends_report_row():
    a = Auditor(minsup=5, n_graphs=10, samples=4)
    a.check_level(2, **_level())
    assert a.report == [{
        "level": 2,
        "checked": {"verdict": 1, "closure": 1, "canonical": 1},
        "n_survivors": 1, "ok": True}]


def test_check_level_below_minsup_survivor():
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="< minsup"):
        a.check_level(2, **_level(gsup_val=3))


def test_check_level_support_above_graph_count():
    a = Auditor(minsup=5, n_graphs=10, samples=4)
    with pytest.raises(AuditError, match="graph count"):
        a.check_level(2, **_level(gsup_val=11, parent_sup=12))


def test_check_level_downward_closure_violation():
    # recorded parent is NOT the rightmost-removed prefix
    a = Auditor(minsup=5, samples=4)
    lvl = _level(parents=(((0, 1, 1, 1, 1),),))
    with pytest.raises(AuditError, match="downward closure"):
        a.check_level(2, **lvl)


def test_check_level_parent_index_out_of_range():
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="downward closure"):
        a.check_level(2, **_level(parent_idx=7))


def test_check_level_monotonicity_violation():
    # child claims more support than its parent — anti-monotone pruning
    # says impossible
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="monotonicity"):
        a.check_level(2, **_level(gsup_val=9, parent_sup=8))


def test_check_level_non_canonical_survivor():
    assert not dfscode.is_canonical(NON_CANON)   # fixture sanity
    a = Auditor(minsup=5, samples=4)
    lvl = _level(code=NON_CANON, parents=(NC_PARENT,))
    with pytest.raises(AuditError, match="not canonical"):
        a.check_level(2, **lvl)


# ---------------------------------------------------------------------------
# whole-prefix audit (checkpoint cuts)
# ---------------------------------------------------------------------------

def _prefix():
    levels = [[PARENT], [CHILD]]
    supports = {PARENT: 8, CHILD: 6}
    return levels, supports


def test_check_levels_clean_prefix():
    levels, supports = _prefix()
    a = Auditor(minsup=5, n_graphs=10, samples=4)
    a.check_levels(levels, supports, start_level=1)
    assert [r["level"] for r in a.report] == [1, 2]
    assert all(r["ok"] for r in a.report)


def test_check_levels_absent_parent():
    levels, supports = _prefix()
    levels[0] = []                              # orphan the child
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="downward closure"):
        a.check_levels(levels, supports, start_level=2)


def test_check_levels_support_inversion():
    levels, supports = _prefix()
    supports[CHILD] = 9                         # > parent's 8
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="monotonicity"):
        a.check_levels(levels, supports, start_level=2)


def test_check_levels_missing_support():
    levels, supports = _prefix()
    del supports[CHILD]
    a = Auditor(minsup=5, samples=4)
    with pytest.raises(AuditError, match="missing a support"):
        a.check_levels(levels, supports, start_level=2)


# ---------------------------------------------------------------------------
# frequent-set gate (partial-result certification)
# ---------------------------------------------------------------------------

def test_audit_frequent_set_passes_host_miner_output():
    db = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(db, 5, max_size=4)
    supports = {c: i.support for c, i in ref.frequent.items()}
    report = audit_frequent_set(ref.levels, supports, 5, n_graphs=10)
    assert len(report) == len(ref.levels)
    assert all(r["ok"] for r in report)


def test_audit_frequent_set_rejects_doctored_support():
    db = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(db, 5, max_size=3)
    supports = {c: i.support for c, i in ref.frequent.items()}
    child = ref.levels[1][0]
    supports[child] = supports[tuple(child[:-1])] + 1   # invert monotone
    with pytest.raises(AuditError):
        audit_frequent_set(ref.levels, supports, 5)


# ---------------------------------------------------------------------------
# overhead model (the CI gate's bound)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cp,np_,w,packed", [
    (64, 2, 1, False), (256, 4, 2, False), (1024, 8, 4, False),
    (1024, 8, 4, True), (512, 8, 1, True), (4096, 16, 8, False),
])
def test_overhead_model_under_five_percent(cp, np_, w, packed):
    m = audit_overhead_model(cp, np_, w, packed=packed)
    assert m["overhead"] < 0.05, m
    assert m["audit_bytes"] > 0 and m["path_bytes"] > m["audit_bytes"]


def test_overhead_model_upload_scales_with_parents_not_candidates():
    few = audit_overhead_model(1024, 8, 4, parents=16)
    many = audit_overhead_model(1024, 8, 4, parents=1024)
    assert few["audit_bytes"] < many["audit_bytes"]
    assert few["parents"] == 16
    # default fanout assumption: cp/4
    assert audit_overhead_model(1024, 8, 4)["parents"] == 256
