"""Watchdog + unified retry-budget units (DESIGN.md §14).

The watchdog's trips are *detection signals*, never control flow: the
injected-hang hook (``faults.maybe_hang``) is the only place a
:class:`HangTimeout` is raised, and the cooperative ``check_run`` the
only place a :class:`DeadlineExceeded` is.  These units pin the phase
deadline policy (default > slack x EWMA > floor), the lazy-clock trip
detection (no thread scheduling required), the monitor thread's
persisted trips, and the jittered-exponential retry budget the
supervisor draws every recovery class from.
"""
import time

import pytest

from repro.core.supervisor import RetryBudget
from repro.runtime import faults
from repro.runtime.watchdog import Watchdog


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    faults.reset_log()
    yield
    faults.clear()
    faults.reset_log()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# run deadline
# ---------------------------------------------------------------------------

def test_run_deadline_checked_cooperatively():
    clk = FakeClock()
    wd = Watchdog(run_deadline_s=10.0, clock=clk).start()
    wd.check_run(level=2)                       # within budget: no raise
    assert wd.run_remaining() == 10.0
    clk.advance(11.0)
    assert wd.run_expired
    with pytest.raises(faults.DeadlineExceeded) as ei:
        wd.check_run(level=3)
    assert ei.value.level == 3
    assert ei.value.deadline_s == 10.0


def test_unbounded_run_never_expires():
    wd = Watchdog().start()
    assert wd.run_remaining() is None
    assert not wd.run_expired
    wd.check_run(level=99)


def test_start_is_idempotent_across_retries():
    clk = FakeClock()
    wd = Watchdog(run_deadline_s=10.0, clock=clk).start()
    clk.advance(4.0)
    wd.start()                                  # a retry does NOT reset
    assert wd.elapsed() == 4.0


# ---------------------------------------------------------------------------
# phase deadline policy
# ---------------------------------------------------------------------------

def test_phase_policy_default_beats_ewma_beats_floor():
    clk = FakeClock()
    wd = Watchdog(phase_floor=1.0, phase_slack=4.0, clock=clk)
    assert wd.phase_deadline() == 1.0           # floor before any sample
    wd.arm(2)
    wd.disarm(observe_s=2.0)
    assert wd.phase_deadline() == 8.0           # slack x EWMA
    wd.disarm(observe_s=1.0)                    # ewma -> 1.5
    assert wd.phase_deadline() == 6.0
    assert Watchdog(phase_default=0.25).phase_deadline() == 0.25


def test_no_policy_means_unarmed():
    wd = Watchdog()                             # no floor, default, sample
    assert wd.phase_deadline() is None
    assert wd.arm(2) is None
    assert not wd.tripped
    wd.close()


def test_phase_deadline_clamped_to_run_remaining():
    clk = FakeClock()
    wd = Watchdog(run_deadline_s=5.0, phase_default=60.0, clock=clk).start()
    clk.advance(3.0)
    assert wd.phase_deadline() == 2.0


def test_sub_unit_slack_rejected():
    with pytest.raises(ValueError, match="phase_slack"):
        Watchdog(phase_slack=0.5)


# ---------------------------------------------------------------------------
# trip detection
# ---------------------------------------------------------------------------

def test_tripped_via_lazy_clock_and_heartbeat_reset():
    clk = FakeClock()
    wd = Watchdog(phase_default=1.0, clock=clk)
    wd.arm(3)
    assert not wd.tripped
    clk.advance(1.5)
    assert wd.tripped                           # no thread needed
    wd.beat(3)                                  # chunk-progress heartbeat
    assert not wd.tripped
    wd.disarm()
    clk.advance(99.0)
    assert not wd.tripped                       # disarmed phase never trips
    wd.close()


def test_monitor_thread_records_and_persists_trips():
    seen = []
    wd = Watchdog(phase_default=0.05, on_trip=seen.append)
    wd.arm(4)
    deadline = time.monotonic() + 5.0
    while not wd.trips and time.monotonic() < deadline:
        time.sleep(0.01)
    wd.close()
    assert wd.trips, "monitor thread never tripped"
    trip = wd.trips[0]
    assert trip["event"] == "watchdog_trip" and trip["level"] == 4
    assert trip["elapsed_s"] >= 0.05
    assert seen == wd.trips                     # persisted as it happened


def test_trip_callback_errors_are_swallowed():
    def boom(info):
        raise RuntimeError("logging must never kill mining")

    wd = Watchdog(phase_default=0.01, on_trip=boom)
    wd.arm(2)
    deadline = time.monotonic() + 5.0
    while not wd.trips and time.monotonic() < deadline:
        time.sleep(0.01)
    wd.close()
    assert wd.trips                             # tripped despite the raise


# ---------------------------------------------------------------------------
# injected hangs (faults.maybe_hang)
# ---------------------------------------------------------------------------

def test_hang_spec_parses_secs():
    spec = faults.FaultSpec.parse("hang@3*2:secs=2.5")
    assert (spec.kind, spec.level, spec.times, spec.secs) == \
        ("hang", 3, 2, 2.5)


def test_maybe_hang_noop_without_schedule():
    t0 = time.monotonic()
    faults.maybe_hang("dispatch", 2, None)
    assert time.monotonic() - t0 < 0.5


def test_maybe_hang_self_clears_without_watchdog():
    faults.install(faults.FaultSchedule.parse("hang@2:secs=0.02"))
    t0 = time.monotonic()
    faults.maybe_hang("dispatch", 2, None)      # rides out the stall
    assert 0.02 <= time.monotonic() - t0 < 5.0


def test_maybe_hang_raises_when_watchdog_trips():
    faults.install(faults.FaultSchedule.parse("hang@3:secs=999"))
    wd = Watchdog(phase_default=0.05)
    wd.arm(3)
    t0 = time.monotonic()
    with pytest.raises(faults.HangTimeout) as ei:
        faults.maybe_hang("dispatch", 3, wd)
    wd.close()
    detect = time.monotonic() - t0
    assert detect < 5.0                         # bounded, not 999s
    assert ei.value.level == 3 and ei.value.waited_s <= detect + 0.1
    assert ei.value.kind == "hang"


def test_maybe_hang_raises_on_expired_run_deadline():
    clk_real = time.monotonic
    faults.install(faults.FaultSchedule.parse("hang@2:secs=999"))
    wd = Watchdog(run_deadline_s=1e-9, clock=clk_real).start()
    with pytest.raises(faults.HangTimeout):
        faults.maybe_hang("chunk", 2, wd)


# ---------------------------------------------------------------------------
# unified retry budget
# ---------------------------------------------------------------------------

def test_retry_budget_exponential_backoff_and_exhaustion():
    b = RetryBudget(max_attempts=3, base=0.1, factor=2.0, cap=10.0,
                    jitter=0.0)
    assert b.spend("kernel") == pytest.approx(0.1)
    assert b.spend("hang") == pytest.approx(0.2)
    assert b.spend("kernel") == pytest.approx(0.4)
    assert b.exhausted
    assert b.spend("state") is None             # exhausted: no charge
    assert b.by_kind == {"kernel": 2, "hang": 1}


def test_retry_budget_backoff_capped():
    b = RetryBudget(max_attempts=10, base=1.0, factor=10.0, cap=2.0,
                    jitter=0.0)
    b.spend("a")
    assert b.spend("a") == pytest.approx(2.0)


def test_retry_budget_jitter_is_seeded_and_bounded():
    vals1 = [RetryBudget(seed=7).spend("x") for _ in range(1)]
    vals2 = [RetryBudget(seed=7).spend("x") for _ in range(1)]
    assert vals1 == vals2                       # deterministic chaos runs
    b = RetryBudget(max_attempts=50, base=0.1, factor=1.0, cap=1.0,
                    jitter=0.25, seed=3)
    for _ in range(50):
        v = b.spend("mixed")
        assert 0.1 <= v <= 0.1 * 1.25 + 1e-12
