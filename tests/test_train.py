"""Training substrate: optimizer, schedules, loop, checkpoint/resume,
gradient compression, data pipeline determinism."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.data.pipeline import TokenPipeline
from repro.models.registry import build, get_smoke_config
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, schedule_lr
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.train_step import make_train_step, init_train_state


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                      warmup_steps=0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_schedules():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule_lr(cfg, jnp.int32(0))) == 0.0
    assert float(schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    wsd = dataclasses.replace(cfg, schedule="wsd", decay_frac=0.2)
    assert float(schedule_lr(wsd, jnp.int32(50))) == pytest.approx(1.0)
    assert float(schedule_lr(wsd, jnp.int32(100))) == pytest.approx(0.1)
    cos = dataclasses.replace(cfg, schedule="cosine")
    assert float(schedule_lr(cos, jnp.int32(100))) == pytest.approx(0.1)


def test_pipeline_determinism_and_sharding():
    p = TokenPipeline(vocab=97, seq_len=32, global_batch=8, seed=1)
    b1 = p.batch(5)
    b2 = p.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # sharded fetch reassembles the global batch exactly
    parts = [p.batch(5, shard=i, n_shards=4)["tokens"] for i in range(4)]
    assert np.array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_loss_decreases_end_to_end(tmp_path):
    cfg = get_smoke_config("minicpm_2b")
    fns = build(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)
    out = train_loop(
        cfg, fns,
        TrainLoopConfig(steps=60, ckpt_every=1000, log_every=1000),
        AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60),
        pipe)
    first, last = np.mean(out["losses"][:5]), np.mean(out["losses"][-5:])
    assert last < first - 0.5, (first, last)


def test_train_checkpoint_resume_exact(tmp_path):
    """Interrupted-and-resumed run must equal the uninterrupted one."""
    cfg = get_smoke_config("gemma2_2b")
    fns = build(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=9)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)

    full = train_loop(cfg, fns, TrainLoopConfig(
        steps=20, ckpt_every=1000, log_every=1000), opt, pipe)

    d = str(tmp_path / "ck")
    train_loop(cfg, fns, TrainLoopConfig(
        steps=10, ckpt_every=10, log_every=1000, ckpt_dir=d), opt, pipe)
    resumed = train_loop(cfg, fns, TrainLoopConfig(
        steps=20, ckpt_every=1000, log_every=1000, ckpt_dir=d), opt, pipe,
        resume=True)
    assert resumed["steps_run"] == 10
    assert_allclose(resumed["losses"][-1], full["losses"][-1], rtol=1e-4)


def test_microbatch_equals_full_batch():
    """Grad accumulation must match the single-batch step (fp32)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2p5_14b"),
                              dtype="float32")
    fns = build(cfg)
    params = fns["init"](jax.random.key(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=None)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

    s1 = make_train_step(cfg, opt, fns["loss_fn"], microbatches=1)
    s4 = make_train_step(cfg, opt, fns["loss_fn"], microbatches=4)
    st = init_train_state(params)
    p1, _, m1 = jax.jit(s1)(params, st, batch)
    st = init_train_state(params)
    p4, _, m4 = jax.jit(s4)(params, st, batch)
    assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(l1, l4):
        # atol covers summation-order wobble of the accumulated grads
        # (params are O(1e-3) after one lr=1e-3 step; bitwise equality is
        # not guaranteed across the two reduction trees)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-6)


DDP_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.data.pipeline import TokenPipeline
    from repro.models.registry import build, get_smoke_config
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.optim.compression import init_error_state, make_train_step_ddp

    cfg = dataclasses.replace(get_smoke_config("minicpm_2b"), dtype="float32")
    fns = build(cfg)
    from repro.runtime import jax_compat
    mesh = jax_compat.make_mesh((4,), ("data",))
    params = fns["init"](jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=50)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)

    for compress in (False, True):
        p = params
        st = adamw_init(p)
        err = init_error_state(p)
        step = make_train_step_ddp(cfg, opt_cfg, fns["loss_fn"], mesh,
                                   compress=compress)
        losses = []
        for s in range(40):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            p, st, err, m = step(p, st, err, batch)
            losses.append(float(m["loss"]))
        drop = np.mean(losses[:5]) - np.mean(losses[-5:])
        print(f"compress={compress} drop={drop:.3f}")
        assert drop > 0.3, (compress, losses[:5], losses[-5:])
    print("DDP-OK")
""")


def test_ddp_compressed_training_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", DDP_SNIPPET],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DDP-OK" in out.stdout
