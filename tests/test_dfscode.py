"""min-dfs-code exactness + canonicality properties (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dfscode import (array_to_code, code_lt, code_to_array,
                                code_to_graph, is_canonical, min_dfs_code,
                                rightmost_path)
from repro.core.graphdb import Graph, random_db


def permute(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel vertex ids by permutation (labels travel with vertices)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    vl = g.vlabels[inv]
    edges = perm[g.edges]
    return Graph(vl, edges, g.elabels)


@st.composite
def small_graphs(draw):
    n_v = draw(st.integers(2, 7))
    n_vlab = draw(st.integers(1, 3))
    n_elab = draw(st.integers(1, 2))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    vl = rng.integers(0, n_vlab, n_v)
    # random spanning tree + a couple extras
    edges = set()
    for i in range(1, n_v):
        j = int(rng.integers(0, i))
        edges.add((j, i))
    for _ in range(draw(st.integers(0, 3))):
        a, b = rng.integers(0, n_v, 2)
        if a != b:
            edges.add((min(int(a), int(b)), max(int(a), int(b))))
    edges = np.array(sorted(edges), np.int32)
    el = rng.integers(0, n_elab, len(edges))
    return Graph(vl, edges, el)


@settings(max_examples=150, deadline=None)
@given(small_graphs(), st.integers(0, 2**31 - 1))
def test_min_code_invariant_under_relabeling(g, seed):
    """The canonical key must not depend on vertex ids — the property that
    makes the MapReduce shuffle key well-defined across partitions."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n_vertices)
    assert min_dfs_code(g) == min_dfs_code(permute(g, perm))


@settings(max_examples=100, deadline=None)
@given(small_graphs())
def test_min_code_is_canonical_and_minimal(g):
    c = min_dfs_code(g)
    assert is_canonical(c)
    # code reconstructs an isomorphic graph: same size, same canonical code
    g2 = code_to_graph(c)
    assert g2.n_edges == g.n_edges
    assert min_dfs_code(g2) == c


@settings(max_examples=100, deadline=None)
@given(small_graphs())
def test_bound_early_exit_consistent(g):
    c = min_dfs_code(g)
    assert min_dfs_code(g, bound=c) == c


def test_single_edge_code():
    g = Graph([1, 0], [(0, 1)], [7])
    assert min_dfs_code(g) == ((0, 1, 0, 7, 1),)


def test_triangle_same_labels():
    g = Graph([0, 0, 0], [(0, 1), (1, 2), (0, 2)], [0, 0, 0])
    c = min_dfs_code(g)
    assert c == ((0, 1, 0, 0, 0), (1, 2, 0, 0, 0), (2, 0, 0, 0, 0))
    assert rightmost_path(c) == (0, 1, 2)


def test_paper_fig5_example():
    """Paper Fig. 5: B-{A,C,D} star.  min code extends A-B with C then D.
    Labels: A=0,B=1,C=2,D=3.  Expected (per paper §IV-A.2):
    (1,2,A,B)(2,3,B,C)(2,4,B,D) -> 0-based (0,1,0,_,1)(1,2,1,_,2)(1,3,1,_,3)."""
    g = Graph([0, 1, 2, 3], [(0, 1), (1, 2), (1, 3)], [0, 0, 0])
    c = min_dfs_code(g)
    assert c == ((0, 1, 0, 0, 1), (1, 2, 1, 0, 2), (1, 3, 1, 0, 3))


def test_noncanonical_generation_path_rejected():
    """Paper Fig. 5(b): building the star via A-B-D first is invalid."""
    bad = ((0, 1, 0, 0, 1), (1, 2, 1, 0, 3), (1, 3, 1, 0, 2))
    assert not is_canonical(bad)


def test_code_array_roundtrip():
    c = ((0, 1, 0, 0, 1), (1, 2, 1, 0, 2), (2, 0, 2, 1, 0))
    a = code_to_array(c, 6)
    assert a.shape == (6, 5)
    assert array_to_code(a) == c


def test_code_lt_total_order_on_sample():
    g = random_db(5, n_vertices=6, seed=3)
    codes = [min_dfs_code(x) for x in g]
    for a in codes:
        assert not code_lt(a, a)
        for b in codes:
            if a != b:
                assert code_lt(a, b) != code_lt(b, a)
