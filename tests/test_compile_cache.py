"""Compile-count regression: shape bucketing must let consecutive
levels hit the `_level_program` jit cache (DESIGN.md §9).

A compiled program is identified by (lru-cache key, input shape
signature): the lru key carries every static config (minsup, backend,
S, M, child vertex width, donation), the shapes carry Cp / store
buckets / schedule rows — two level dispatches agreeing on BOTH run the
same XLA executable, two differing on EITHER pay a fresh compile.  The
tracer below records exactly that pair per dispatch, so the asserted
counts are compile counts, not cache-info proxies.

The DB is a set of identical label-free path graphs: every level keeps
exactly one frequent pattern (the path), candidate counts stay tiny and
flat, and mining runs as deep as max_size allows — the pathological
case for per-level recompiles (the unbucketed pipeline compiles one
program PER level because the vertex-slot axis K grows every level).
"""
import numpy as np
import pytest

import jax._src.array as _jarr

from repro.core import level_step, mining
from repro.core.graphdb import Graph
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig


def path_db(n_graphs=6, length=9):
    def path(n):
        return Graph(np.zeros(n, np.int32),
                     np.stack([np.arange(n - 1), np.arange(1, n)], 1),
                     np.zeros(n - 1, np.int32))
    return [path(length) for _ in range(n_graphs)]


class _ProgramTracer:
    """Record one (static key, arg shapes) signature per dispatch —
    the exact identity XLA compiles under."""

    def __init__(self, monkeypatch):
        self.signatures = set()
        orig = level_step._level_program

        def traced(*key):
            fn = orig(*key)

            def wrapper(*args):
                self.signatures.add(
                    (key, tuple(np.shape(a) for a in args)))
                return fn(*args)
            return wrapper

        monkeypatch.setattr(level_step, "_level_program", traced)

    @property
    def n_compiles(self):
        return len(self.signatures)


def _mine(bucket: bool, monkeypatch, graphs=None, **kw):
    tracer = _ProgramTracer(monkeypatch)
    cfg = MirageConfig(minsup=6, n_partitions=2, max_size=8,
                       bucket_shapes=bucket, **kw)
    res = Mirage(cfg).fit(path_db() if graphs is None else graphs)
    return res, tracer


def test_bucketing_caps_compiles_on_deep_run(monkeypatch):
    """>=6 levels, <=3 distinct compiles bucketed vs one-per-level
    unbucketed — the tentpole contract."""
    graphs = path_db()
    ref = mine_host(graphs, 6, max_size=8)

    res_b, tr_b = _mine(True, monkeypatch)
    assert len(res_b.stats) >= 6, "DB must mine at least 6 levels"
    assert tr_b.n_compiles <= 3, (
        f"{tr_b.n_compiles} distinct level programs for "
        f"{len(res_b.stats)} levels with bucketing on")

    res_u, tr_u = _mine(False, monkeypatch)
    assert tr_u.n_compiles >= len(res_u.stats), (
        "unbucketed levels each present a fresh shape (K grows)")

    # bucketed, unbucketed, legacy and the host oracle agree bit-for-bit
    # on real slots
    res_l = Mirage(MirageConfig(minsup=6, n_partitions=2, max_size=8,
                                pipeline="legacy")).fit(graphs)
    assert sorted(res_b.supports.items()) == sorted(res_u.supports.items())
    assert sorted(res_b.supports.items()) == sorted(res_l.supports.items())
    assert sorted(res_b.supports.items()) == sorted(
        (c, i.support) for c, i in ref.frequent.items())


def test_bucketed_run_keeps_one_sync_per_level(monkeypatch):
    """The PR-2 wire contract survives bucketing: exactly one
    device→host transfer per mined level (counted at jax's ArrayImpl
    fetch point), padding never adds a sync."""
    graphs = path_db()
    cfg = MirageConfig(minsup=6, n_partitions=2, max_size=6,
                       bucket_shapes=True)

    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = Mirage(cfg).fit(graphs)
    finally:
        _jarr.ArrayImpl._value = orig

    assert sum(st.escalations for st in res.stats) == 0
    assert counts["n"] == len(res.stats), (
        f"{counts['n']} device→host transfers for {len(res.stats)} levels")


def test_bucketed_wire_supports_match_legacy(monkeypatch):
    """Every level's wire support vector (real slots) must match the
    legacy two-program driver's — bucket padding cannot leak into the
    packed wire."""
    graphs = path_db(n_graphs=5, length=8)
    wires = []
    orig = mining.dispatch_level

    def spy(*args, **kw):
        pending = orig(*args, **kw)
        inner = pending.finish

        def finish():
            out = inner()
            wires.append(np.asarray(out.wire.gsup))
            return out

        pending.finish = finish
        return pending

    monkeypatch.setattr(mining, "dispatch_level", spy)
    res = Mirage(MirageConfig(minsup=5, n_partitions=1, max_size=5,
                              bucket_shapes=True)).fit(graphs)
    monkeypatch.setattr(mining, "dispatch_level", orig)

    legacy = Mirage(MirageConfig(minsup=5, n_partitions=1, max_size=5,
                                 pipeline="legacy")).fit(graphs)
    assert sorted(res.supports.items()) == sorted(legacy.supports.items())
    assert len(wires) == len(res.stats)
    for st, gsup in zip(res.stats, wires):
        assert gsup.shape[0] == st.n_candidates  # unpack slices padding off


def test_fused_tile_c_pinned_per_run(monkeypatch):
    """ISSUE-8: the bucketed fused dispatch used to hardwire tile_c=8
    regardless of the run's parent grouping.  The driver now picks the
    tile width ONCE, from the level-2 grouping's adaptive choice, and
    dispatches every level with it — so (a) all per-level schedules
    agree on tile_c, (b) the pin is the adaptive choice, and (c) the
    pin adds no level-program compiles (the <=3 contract holds)."""
    from repro.core import candgen

    widths = []
    orig = candgen.schedule_candidates

    def spy(meta, *a, **kw):
        sched = orig(meta, *a, **kw)
        widths.append(sched.tile_c)
        return sched

    monkeypatch.setattr(candgen, "schedule_candidates", spy)
    monkeypatch.setattr(mining, "schedule_candidates", spy)
    res, tr = _mine(True, monkeypatch, backend="fused_interpret")
    assert len(res.stats) >= 6
    assert widths, "fused dispatches must build schedules"
    # the FIRST call is the driver's pin computation (adaptive, meta
    # only); every later call is a dispatch passing the pin through —
    # one distinct width means pin == the adaptive level-2 choice
    assert len(set(widths)) == 1, (
        f"tile_c must be pinned for the run, saw {sorted(set(widths))}")
    assert tr.n_compiles <= 3, (
        f"the tile_c pin must not add compiles, saw {tr.n_compiles}")


def test_device_loop_one_program_one_fetch(monkeypatch):
    """DESIGN.md §13 compile + transfer budget: a non-escalating
    device_loop run compiles exactly ONE whole-run program (<=3 with
    the escalation-retrace allowance) and performs exactly ONE
    device→host fetch — the end-of-run wire."""
    from repro.core import device_loop as dloop

    signatures = set()
    orig_prog = dloop._run_program

    def traced(*key):
        fn = orig_prog(*key)

        def wrapper(*args):
            signatures.add((key, tuple(np.shape(a) for a in args)))
            return fn(*args)
        return wrapper

    monkeypatch.setattr(dloop, "_run_program", traced)

    graphs = path_db()
    ref = mine_host(graphs, 6, max_size=8)
    cfg = MirageConfig(minsup=6, n_partitions=2, max_size=8,
                       backend="ref", pipeline="device_loop")
    miner = Mirage(cfg)

    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = miner.fit(graphs)
    finally:
        _jarr.ArrayImpl._value = orig

    assert miner.last_device_loop["completed"], miner.last_device_loop
    assert len(res.stats) >= 6, "DB must mine at least 6 levels"
    assert len(signatures) == 1, (
        f"{len(signatures)} run programs for a non-escalating run")
    assert counts["n"] == 1, (
        f"{counts['n']} device→host transfers for the whole run "
        f"({len(res.stats)} levels)")
    assert sorted(res.supports.items()) == sorted(
        (c, i.support) for c, i in ref.frequent.items())


def test_fused_schedule_bucketing_matches_ref(monkeypatch):
    """The fused backend's bucketed schedule (invalid pad tiles, parked
    inverse permutation) must agree with the ref backend compile-for-
    compile and support-for-support."""
    res_f, tr_f = _mine(True, monkeypatch, backend="fused_interpret")
    assert tr_f.n_compiles <= 3
    res_r = Mirage(MirageConfig(minsup=6, n_partitions=2, max_size=8,
                                bucket_shapes=True,
                                backend="ref")).fit(path_db())
    assert sorted(res_f.supports.items()) == sorted(res_r.supports.items())
