"""Elastic scaling: a mining job checkpointed under W workers resumes
under a DIFFERENT worker count with identical results (the state is
saved unsharded and re-laid-out on load)."""
import os
import subprocess
import sys
import textwrap

SNIPPET = textwrap.dedent("""
    import os, shutil, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    ck = sys.argv[1]
    graphs = pubchem_like_db(48, seed=21, avg_edges=10)
    ref = mine_host(graphs, 12, max_size=4)

    def mesh(w):
        return MiningMesh(jax_compat.make_mesh((w,), ("w",)))

    # phase 1: run 2 levels on 4 workers, checkpointing
    cfg = MirageConfig(minsup=12, n_partitions=16, max_size=2,
                       checkpoint_dir=ck)
    Mirage(cfg, mesh(4)).fit(graphs)

    # phase 2: resume to completion on 8 workers (elastic grow)
    cfg2 = MirageConfig(minsup=12, n_partitions=16, max_size=4,
                        checkpoint_dir=ck)
    res = Mirage(cfg2, mesh(8)).fit(graphs, resume=True)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support
    print("ELASTIC-OK")
""")


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_elastic_resume_different_worker_count(tmp_path):
    assert "ELASTIC-OK" in _run_snippet(SNIPPET, tmp_path / "ck")


GROW_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    ck = sys.argv[1]
    graphs = pubchem_like_db(24, seed=31, avg_edges=10)
    ref = mine_host(graphs, 6, max_size=4)

    def mesh(w):
        return MiningMesh(jax_compat.make_mesh((w,), ("w",)))

    # phase 1: 2 levels on ONE worker, checkpointing
    cfg = MirageConfig(minsup=6, n_partitions=4, max_size=2,
                       checkpoint_dir=ck)
    Mirage(cfg, mesh(1)).fit(graphs)

    # phase 2: resume to completion on TWO virtual workers
    cfg2 = MirageConfig(minsup=6, n_partitions=4, max_size=4,
                        checkpoint_dir=ck)
    res = Mirage(cfg2, mesh(2)).fit(graphs, resume=True)

    # bit-identical to the uninterrupted run AND the host oracle
    full = Mirage(MirageConfig(minsup=6, n_partitions=4,
                               max_size=4)).fit(graphs)
    assert sorted(res.supports.items()) == sorted(full.supports.items())
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support
    print("GROW-OK")
""")


def test_elastic_resume_one_to_two_workers(tmp_path):
    """Checkpoint mid-run on a single worker, resume on a 2-worker mesh:
    frequent sets and supports must be bit-identical."""
    assert "GROW-OK" in _run_snippet(GROW_SNIPPET, tmp_path / "ck")


SKEW_SNIPPET = textwrap.dedent("""
    import jax, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    # skewed DB: scheme-1 round-robin lands every heavy graph on
    # partition 0, overloading worker 0 under the blocked assignment
    heavy = iter(random_db(6, n_vertices=9, extra_edge_prob=0.6,
                           n_vlabels=2, n_elabels=1, seed=1))
    light = iter(random_db(18, n_vertices=3, extra_edge_prob=0.2,
                           n_vlabels=2, n_elabels=1, seed=2))
    graphs = [next(heavy) if i % 4 == 0 else next(light)
              for i in range(24)]
    ref = mine_host(graphs, 6, max_size=3)
    mesh = MiningMesh(jax_compat.make_mesh((2,), ("w",)))

    cfg = MirageConfig(minsup=6, n_partitions=4, scheme=1, max_size=3,
                       rebalance=True, rebalance_threshold=1.1)
    res = Mirage(cfg, mesh).fit(graphs)
    assert any(s.rebalanced for s in res.stats), \\
        [s.imbalance for s in res.stats]

    # rebalancing must be invisible in the results
    cfg2 = MirageConfig(minsup=6, n_partitions=4, scheme=1, max_size=3,
                        rebalance=False)
    res2 = Mirage(cfg2, mesh).fit(graphs)
    assert sorted(res.supports.items()) == sorted(res2.supports.items())
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support

    # shape bucketing must not leak padding into the LPT cost signal:
    # the same skewed DB under small bucket floors must still trip the
    # repack AND still be invisible in the results (padded candidates
    # contribute zero embed-cost; padded partitions don't exist)
    cfg3 = MirageConfig(minsup=6, n_partitions=4, scheme=1, max_size=3,
                        rebalance=True, rebalance_threshold=1.1,
                        bucket_shapes=True, bucket_c_floor=8,
                        bucket_s_floor=4, bucket_k_floor=4)
    res3 = Mirage(cfg3, mesh).fit(graphs)
    assert any(s.rebalanced for s in res3.stats), \\
        [s.imbalance for s in res3.stats]
    assert sorted(res3.supports.items()) == sorted(res2.supports.items())
    for a, b in zip(res.stats, res3.stats):
        assert abs(a.imbalance - b.imbalance) < 1e-3, (a, b)
    print("SKEW-OK")
""")


def test_straggler_rebalance_fires_and_is_invariant():
    """Skewed partitions must trip the on-device LPT repack
    (rebalanced=True in the level stats) without changing any result."""
    assert "SKEW-OK" in _run_snippet(SKEW_SNIPPET)
