"""Elastic scaling: a mining job checkpointed under W workers resumes
under a DIFFERENT worker count with identical results (the state is
saved unsharded and re-laid-out on load)."""
import os
import subprocess
import sys
import textwrap

SNIPPET = textwrap.dedent("""
    import os, shutil, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    ck = sys.argv[1]
    graphs = pubchem_like_db(48, seed=21, avg_edges=10)
    ref = mine_host(graphs, 12, max_size=4)

    def mesh(w):
        return MiningMesh(jax_compat.make_mesh((w,), ("w",)))

    # phase 1: run 2 levels on 4 workers, checkpointing
    cfg = MirageConfig(minsup=12, n_partitions=16, max_size=2,
                       checkpoint_dir=ck)
    Mirage(cfg, mesh(4)).fit(graphs)

    # phase 2: resume to completion on 8 workers (elastic grow)
    cfg2 = MirageConfig(minsup=12, n_partitions=16, max_size=4,
                        checkpoint_dir=ck)
    res = Mirage(cfg2, mesh(8)).fit(graphs, resume=True)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support
    print("ELASTIC-OK")
""")


def test_elastic_resume_different_worker_count(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", SNIPPET, str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ELASTIC-OK" in out.stdout
