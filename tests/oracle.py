"""Independent brute-force FSM oracle built on networkx isomorphism.

Enumerates every connected edge-subset (up to a size bound) of every
database graph, groups them by exact labeled isomorphism
(``networkx.is_isomorphic``), and thresholds on the number of distinct
database graphs containing each class.  Deliberately shares no code with
``repro.core`` beyond the Graph container.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import networkx as nx

from repro.core.graphdb import Graph


def to_nx(g: Graph, edge_subset=None) -> nx.Graph:
    G = nx.Graph()
    edges = list(range(g.n_edges)) if edge_subset is None else sorted(edge_subset)
    for k in edges:
        u, v = int(g.edges[k][0]), int(g.edges[k][1])
        G.add_node(u, label=int(g.vlabels[u]))
        G.add_node(v, label=int(g.vlabels[v]))
        G.add_edge(u, v, label=int(g.elabels[k]))
    return G


def _node_match(a, b):
    return a["label"] == b["label"]


def _edge_match(a, b):
    return a["label"] == b["label"]


def connected_edge_subsets(g: Graph, max_edges: int) -> list[frozenset[int]]:
    """All connected edge-subsets of g with 1..max_edges edges."""
    incident: dict[int, set[int]] = {}
    for k, (u, v) in enumerate(map(tuple, g.edges)):
        incident.setdefault(int(u), set()).add(k)
        incident.setdefault(int(v), set()).add(k)

    seen: set[frozenset[int]] = set()
    frontier = [frozenset([k]) for k in range(g.n_edges)]
    seen.update(frontier)
    out = list(frontier)
    for _ in range(max_edges - 1):
        nxt = []
        for s in frontier:
            verts = set()
            for k in s:
                verts.add(int(g.edges[k][0]))
                verts.add(int(g.edges[k][1]))
            grow = set()
            for v in verts:
                grow |= incident.get(v, set())
            for k in grow - s:
                t = s | {k}
                if t not in seen:
                    seen.add(t)
                    nxt.append(t)
        out.extend(nxt)
        frontier = nxt
    return out


def brute_force_frequent(
    graphs: Sequence[Graph], minsup: int, max_edges: int
) -> list[tuple[nx.Graph, set[int], int]]:
    """Returns [(representative_pattern, supporting_graph_ids, n_edges)]."""
    classes: list[tuple[nx.Graph, set[int], int]] = []
    for gi, g in enumerate(graphs):
        for s in connected_edge_subsets(g, max_edges):
            P = to_nx(g, s)
            ne = P.number_of_edges()
            for (Q, ids, qe) in classes:
                if qe == ne and nx.is_isomorphic(
                        P, Q, node_match=_node_match, edge_match=_edge_match):
                    ids.add(gi)
                    break
            else:
                classes.append((P, {gi}, ne))
    return [(P, ids, ne) for (P, ids, ne) in classes if len(ids) >= minsup]


def counts_by_level(freq, max_edges: int) -> list[int]:
    out = [0] * max_edges
    for (_, _, ne) in freq:
        out[ne - 1] += 1
    return out
