"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # property sweep skipped; fixed-shape tests still run
    HAVE_HYPOTHESIS = False

from repro.core.candgen import generate_candidates
from repro.core.embedding import build_edge_ol, candidate_meta, level1_ol
from repro.core.graphdb import paper_toy_db, random_db
from repro.core.host_miner import frequent_edges
from repro.kernels.embedding_join import embedding_join_pallas
from repro.kernels.ops import level_supports
from repro.kernels.ref import embedding_join_ref, support_count_ref
from repro.kernels.support_count import support_count_pallas


def _random_level(rng, C=5, P=3, G=16, M=8, K=3, T=4, F=6):
    """Random-but-consistent join inputs (ids in [0, 32), PAD=-1)."""
    pol = rng.integers(0, 32, (P, G, M, K)).astype(np.int32)
    pmask = (rng.random((P, G, M)) < 0.7)
    # emulate PAD tail on some vertex slots
    kill = rng.random((P, G, M, K)) < 0.15
    pol = np.where(kill, -1, pol)
    src = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    dst = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    emask = (rng.random((T, G, F)) < 0.7)
    src = np.where(emask, src, -1)
    dst = np.where(emask, dst, -1)
    meta = np.stack([
        rng.integers(0, P, C),            # parent
        rng.integers(0, K, C),            # stub
        rng.integers(0, K, C),            # to
        rng.integers(0, 2, C),            # fwd
        rng.integers(0, T, C),            # triple
    ], axis=1).astype(np.int32)
    return tuple(map(jnp.asarray, (meta, pol, pmask, src, dst, emask)))


@pytest.mark.parametrize("shape", [
    dict(C=4, P=2, G=8, M=4, K=2, T=3, F=4),
    dict(C=7, P=5, G=16, M=8, K=4, T=4, F=8),
    dict(C=3, P=3, G=32, M=16, K=6, T=2, F=16),
    dict(C=9, P=4, G=24, M=5, K=3, T=5, F=7),   # non-pow2 everything
])
def test_join_kernel_matches_ref(shape):
    rng = np.random.default_rng(42 + shape["G"])
    args = _random_level(rng, **shape)
    m_ref, c_ref = embedding_join_ref(*args)
    meta, pol, pmask, src, dst, emask = args
    g = pol.shape[1]
    tg = 8 if g % 8 == 0 else g
    m_k, c_k = embedding_join_pallas(
        meta, pol, pmask.astype(jnp.int8), src, dst,
        emask.astype(jnp.int8), tile_g=tg, interpret=True)
    assert_allclose(np.asarray(m_k), np.asarray(m_ref))
    assert_allclose(np.asarray(c_k), np.asarray(c_ref))


@pytest.mark.parametrize("C,G,tc,tg", [(8, 128, 4, 32), (16, 64, 8, 64),
                                       (4, 256, 2, 128)])
def test_support_count_matches_ref(C, G, tc, tg):
    rng = np.random.default_rng(C * G)
    matched = jnp.asarray(rng.integers(0, 2, (C, G)).astype(np.int32))
    count = jnp.asarray(rng.integers(0, 9, (C, G)).astype(np.int32))
    s_ref, e_ref = support_count_ref(matched, count)
    s_k, e_k = support_count_pallas(matched, count, tile_c=tc, tile_g=tg,
                                    interpret=True)
    assert_allclose(np.asarray(s_k), np.asarray(s_ref))
    assert_allclose(np.asarray(e_k), np.asarray(e_ref))


def test_ops_wrapper_interpret_vs_ref_end_to_end():
    """Real mining inputs (paper toy DB), kernel path vs ref path."""
    graphs = paper_toy_db()
    alphabet, _ = frequent_edges(graphs, 2)
    triples = sorted({t for c in alphabet.canonical()
                      for t in (c, (c[2], c[1], c[0]))})
    eol = build_edge_ol(graphs, triples)
    codes = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
    level = level1_ol(codes, eol, max_embeddings=8)
    cands = generate_candidates(codes, alphabet)
    meta = jnp.asarray(candidate_meta(cands, eol))
    src, dst, em = map(jnp.asarray, (eol.src, eol.dst, eol.mask))

    s_ref, e_ref = level_supports(meta, level.ol, level.mask, src, dst, em,
                                  backend="ref")
    s_k, e_k = level_supports(meta, level.ol, level.mask, src, dst, em,
                              backend="interpret", tile_g=8, tile_c=4)
    assert_allclose(np.asarray(s_k), np.asarray(s_ref))
    assert_allclose(np.asarray(e_k), np.asarray(e_ref))
    # and the supports are the true ones (host oracle cross-check happens
    # in test_embedding.py; here: A-B-C & A-B-D frequent, A-B-E not)
    sup_by_code = {cands[i].code: int(s_ref[i]) for i in range(len(cands))}
    abc = ((0, 1, 0, 0, 1), (1, 2, 1, 0, 2))
    abe = ((0, 1, 0, 0, 1), (1, 2, 1, 0, 4))
    assert sup_by_code[abc] == 2
    assert sup_by_code[abe] == 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 10), st.integers(1, 24))
    def test_join_kernel_property_sweep(seed, c, g):
        rng = np.random.default_rng(seed)
        args = _random_level(rng, C=c, P=3, G=g, M=4, K=3, T=3, F=5)
        m_ref, c_ref = embedding_join_ref(*args)
        meta, pol, pmask, src, dst, emask = args
        m_k, c_k = embedding_join_pallas(
            meta, pol, pmask.astype(jnp.int8), src, dst,
            emask.astype(jnp.int8), tile_g=g, interpret=True)
        assert_allclose(np.asarray(m_k), np.asarray(m_ref))
        assert_allclose(np.asarray(c_k), np.asarray(c_ref))
