"""Dry-run spec layer: input shapes, applicability rules, step mapping."""
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, cell_applicable, shape_lowers
from repro.launch.specs import cache_specs_struct, input_specs
from repro.models.registry import ARCHS, get_config


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288
    assert shape_lowers(SHAPES["train_4k"]) == "train_step"
    assert shape_lowers(SHAPES["decode_32k"]) == "decode_step"
    assert shape_lowers(SHAPES["long_500k"]) == "decode_step"
    assert shape_lowers(SHAPES["prefill_32k"]) == "prefill_step"


def test_long500k_applicability():
    runnable = [a for a in ARCHS
                if cell_applicable(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runnable) == ["qwen2_vl_72b"] or True  # computed below
    names = sorted(get_config(a).name for a in runnable)
    assert names == ["xlstm-1.3b", "zamba2-2.7b"], names


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        ok, _ = cell_applicable(cfg, shape)
        if not ok:
            continue
        batch = input_specs(cfg, shape)
        B = shape.global_batch
        s_tok = 1 if shape.kind == "decode" else shape.seq_len
        if cfg.family == "vlm":
            assert batch["embeds"].shape == (B, s_tok, cfg.d_model)
            assert batch["positions3"].shape == (3, B, s_tok)
        else:
            assert batch["tokens"].shape == (B, s_tok)
        if shape.kind == "train":
            assert batch["labels"].shape == (B, shape.seq_len)
        if cfg.family in ("audio", "encdec") and shape.kind != "decode":
            assert batch["frames"].shape == (B, cfg.encoder_frames,
                                             cfg.d_model)


@pytest.mark.parametrize("arch", ["qwen2p5_14b", "deepseek_v2_lite",
                                  "zamba2_2p7b", "xlstm_1p3b",
                                  "whisper_base"])
def test_cache_specs_families(arch):
    cfg = get_config(arch)
    cache = cache_specs_struct(cfg, SHAPES["decode_32k"])
    leaves = [l for l in __import__("jax").tree_util.tree_leaves(cache)]
    assert leaves, "cache must be non-empty"
    # every kv leaf covers the full cache length
    if cfg.family == "dense":
        assert any(l.shape[2] == SHAPES["decode_32k"].seq_len
                   for l in leaves if l.ndim >= 3)
    if cfg.mla:
        assert any(l.shape[-1] == cfg.kv_lora for l in leaves)
