"""Differential conformance: the distributed miner vs the exact host
oracle, pattern-for-pattern and support-for-support, across the full
backend × reduce-mode × partition-scheme matrix.

The compiled kernel backends (``fused``, ``pallas``) only lower on TPU;
off-TPU each resolves to its interpret-mode twin so the matrix always
runs end-to-end with identical semantics (the interpret kernels execute
the same Pallas program, un-jitted).

A deeper Hypothesis-driven sweep rides along when hypothesis is
installed (random DBs, random configs); the seeded matrix above is the
always-on floor.
"""
import jax
import numpy as np
import pytest

from repro.core.graphdb import Graph, random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig

BACKENDS = ["fused", "fused_interpret", "pallas", "ref"]
_ON_TPU = jax.default_backend() == "tpu"
_CPU_TWIN = {"fused": "fused_interpret", "pallas": "interpret"}


def resolve_backend(backend: str) -> str:
    if _ON_TPU:
        return backend
    return _CPU_TWIN.get(backend, backend)


def canon_host(res):
    return sorted((c, i.support) for c, i in res.frequent.items())


def canon_dist(res):
    return sorted(res.supports.items())


_DBS = {}


def conformance_db():
    """One shared seeded DB + host-oracle result for the whole matrix."""
    if "db" not in _DBS:
        graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                           n_vlabels=3, n_elabels=2, seed=42)
        _DBS["db"] = (graphs, mine_host(graphs, 5, max_size=3))
    return _DBS["db"]


@pytest.mark.parametrize("scheme", [1, 2])
@pytest.mark.parametrize("reduce", ["psum", "reduce_scatter"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_matrix(backend, reduce, scheme):
    graphs, ref = conformance_db()
    cfg = MirageConfig(minsup=5, n_partitions=2, scheme=scheme,
                       max_size=3, reduce=reduce,
                       backend=resolve_backend(backend))
    res = Mirage(cfg).fit(graphs)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels], (
        backend, reduce, scheme)
    assert canon_dist(res) == canon_host(ref), (backend, reduce, scheme)


@pytest.mark.parametrize("pipeline", ["single_sync", "legacy"])
def test_conformance_pipelines_agree(pipeline):
    """Both driver pipelines must produce the oracle result — the legacy
    two-program driver doubles as a differential check on the fused
    level program."""
    graphs, ref = conformance_db()
    cfg = MirageConfig(minsup=5, n_partitions=2, max_size=3,
                       pipeline=pipeline)
    res = Mirage(cfg).fit(graphs)
    assert canon_dist(res) == canon_host(ref), pipeline


def test_escalation_valve_adversarial_overflow():
    """Adversarial DB: one vertex/edge label and dense wiring make the
    level-2 embedding counts blow straight through the initial M cap.
    The valve must escalate (observable in stats) and land on exact
    supports with zero residual overflow."""
    graphs = random_db(8, n_vertices=8, extra_edge_prob=0.9, n_vlabels=1,
                       n_elabels=1, seed=7)
    ref = mine_host(graphs, 4, max_size=3)
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=3,
                       max_embeddings=2, escalate_on_overflow=True,
                       max_embeddings_limit=4096)
    res = Mirage(cfg).fit(graphs)
    assert sum(st.escalations for st in res.stats) > 0, (
        "the M cap must actually overflow for this DB")
    assert res.total_overflow == 0
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    assert canon_dist(res) == canon_host(ref)


def test_escalation_valve_respects_ceiling():
    """With a hard ceiling below the need, overflow must be *reported*
    (exactness telemetry), never silently swallowed."""
    graphs = random_db(8, n_vertices=8, extra_edge_prob=0.9, n_vlabels=1,
                       n_elabels=1, seed=7)
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=3,
                       max_embeddings=2, escalate_on_overflow=True,
                       max_embeddings_limit=4)
    res = Mirage(cfg).fit(graphs)
    assert res.total_overflow > 0


# ---------------------------------------------------------------------------
# hypothesis sweep (optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    _HAVE_HYP = True
except ImportError:                                        # pragma: no cover
    _HAVE_HYP = False

if _HAVE_HYP:
    @st.composite
    def small_dbs(draw):
        n = draw(st.integers(6, 14))
        seed = draw(st.integers(0, 2**31 - 1))
        return random_db(n, n_vertices=6, vertex_jitter=1,
                         extra_edge_prob=0.3, n_vlabels=3, n_elabels=2,
                         seed=seed)

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(small_dbs(),
           st.sampled_from(["fused_interpret", "ref"]),
           st.sampled_from(["psum", "reduce_scatter"]),
           st.sampled_from([1, 2]),
           st.sampled_from([1, 2]))
    def test_conformance_hypothesis(graphs, backend, reduce, scheme, parts):
        minsup = max(2, len(graphs) // 3)
        ref = mine_host(graphs, minsup, max_size=3)
        cfg = MirageConfig(minsup=minsup, n_partitions=parts, scheme=scheme,
                           max_size=3, reduce=reduce,
                           backend=resolve_backend(backend))
        res = Mirage(cfg).fit(graphs)
        assert canon_dist(res) == canon_host(ref), (backend, reduce, scheme)
