"""Differential conformance: the distributed miner vs the exact host
oracle, pattern-for-pattern and support-for-support, across the full
backend × reduce-mode × partition-scheme matrix.

The compiled kernel backends (``fused``, ``pallas``) only lower on TPU;
off-TPU each resolves to its interpret-mode twin so the matrix always
runs end-to-end with identical semantics (the interpret kernels execute
the same Pallas program, un-jitted).

A deeper Hypothesis-driven sweep rides along when hypothesis is
installed (random DBs, random configs); the seeded matrix above is the
always-on floor.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.graphdb import Graph, random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig

BACKENDS = ["fused", "fused_interpret", "pallas", "ref"]
_ON_TPU = jax.default_backend() == "tpu"
_CPU_TWIN = {"fused": "fused_interpret", "pallas": "interpret"}


def resolve_backend(backend: str) -> str:
    if _ON_TPU:
        return backend
    return _CPU_TWIN.get(backend, backend)


def canon_host(res):
    return sorted((c, i.support) for c, i in res.frequent.items())


def canon_dist(res):
    return sorted(res.supports.items())


_DBS = {}


def conformance_db():
    """One shared seeded DB + host-oracle result for the whole matrix."""
    if "db" not in _DBS:
        graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                           n_vlabels=3, n_elabels=2, seed=42)
        _DBS["db"] = (graphs, mine_host(graphs, 5, max_size=3))
    return _DBS["db"]


@pytest.mark.parametrize("scheme", [1, 2])
@pytest.mark.parametrize("reduce", ["psum", "reduce_scatter"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_matrix(backend, reduce, scheme):
    graphs, ref = conformance_db()
    cfg = MirageConfig(minsup=5, n_partitions=2, scheme=scheme,
                       max_size=3, reduce=reduce,
                       backend=resolve_backend(backend))
    res = Mirage(cfg).fit(graphs)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels], (
        backend, reduce, scheme)
    assert canon_dist(res) == canon_host(ref), (backend, reduce, scheme)


@pytest.mark.parametrize("pipeline", ["single_sync", "legacy"])
def test_conformance_pipelines_agree(pipeline):
    """Both driver pipelines must produce the oracle result — the legacy
    two-program driver doubles as a differential check on the fused
    level program."""
    graphs, ref = conformance_db()
    cfg = MirageConfig(minsup=5, n_partitions=2, max_size=3,
                       pipeline=pipeline)
    res = Mirage(cfg).fit(graphs)
    assert canon_dist(res) == canon_host(ref), pipeline


def test_escalation_valve_adversarial_overflow():
    """Adversarial DB: one vertex/edge label and dense wiring make the
    level-2 embedding counts blow straight through the initial M cap.
    The valve must escalate (observable in stats) and land on exact
    supports with zero residual overflow."""
    graphs = random_db(8, n_vertices=8, extra_edge_prob=0.9, n_vlabels=1,
                       n_elabels=1, seed=7)
    ref = mine_host(graphs, 4, max_size=3)
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=3,
                       max_embeddings=2, escalate_on_overflow=True,
                       max_embeddings_limit=4096)
    res = Mirage(cfg).fit(graphs)
    assert sum(st.escalations for st in res.stats) > 0, (
        "the M cap must actually overflow for this DB")
    assert res.total_overflow == 0
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    assert canon_dist(res) == canon_host(ref)


def test_escalation_valve_respects_ceiling():
    """With a hard ceiling below the need, overflow must be *reported*
    (exactness telemetry), never silently swallowed."""
    graphs = random_db(8, n_vertices=8, extra_edge_prob=0.9, n_vlabels=1,
                       n_elabels=1, seed=7)
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=3,
                       max_embeddings=2, escalate_on_overflow=True,
                       max_embeddings_limit=4)
    res = Mirage(cfg).fit(graphs)
    assert res.total_overflow > 0


# ---------------------------------------------------------------------------
# checkpoint/resume across bucket boundaries (runtime/checkpoint.py
# padding round-trips: save and resume may disagree on bucket floors —
# or on bucketing at all — AND on worker count)
# ---------------------------------------------------------------------------

RESUME_BUCKET_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    ck = sys.argv[1]
    graphs = pubchem_like_db(24, seed=31, avg_edges=10)
    ref = mine_host(graphs, 6, max_size=4)

    def mesh(w):
        return MiningMesh(jax_compat.make_mesh((w,), ("w",)))

    def check(res, tag):
        assert [set(l) for l in res.levels] == \\
            [set(l) for l in ref.levels], tag
        for code, sup in res.supports.items():
            assert sup == ref.frequent[code].support, (tag, code)

    # phase 1: 2 levels on TWO workers under SMALL bucket floors
    cfg = MirageConfig(minsup=6, n_partitions=4, max_size=2,
                       checkpoint_dir=ck, bucket_shapes=True,
                       bucket_c_floor=8, bucket_s_floor=4,
                       bucket_k_floor=4)
    Mirage(cfg, mesh(2)).fit(graphs)

    # phase 2: resume to completion on ONE worker at a DIFFERENT bucket
    # boundary (every floor changed) — the checkpoint's canonical store
    # must re-pad into the new family
    cfg2 = MirageConfig(minsup=6, n_partitions=4, max_size=4,
                        checkpoint_dir=ck, bucket_shapes=True,
                        bucket_c_floor=32, bucket_s_floor=16,
                        bucket_k_floor=8)
    check(Mirage(cfg2, mesh(1)).fit(graphs, resume=True), "rebucket")

    # phase 3: the SAME checkpoint resumed with bucketing OFF on two
    # workers — padding must not have leaked into the saved state
    cfg3 = MirageConfig(minsup=6, n_partitions=4, max_size=4,
                        checkpoint_dir=ck, bucket_shapes=False)
    check(Mirage(cfg3, mesh(2)).fit(graphs, resume=True), "unbucketed")

    # phase 4: an UNBUCKETED checkpoint resumed bucketed (reverse trip)
    ck2 = ck + "-rev"
    cfg4 = MirageConfig(minsup=6, n_partitions=4, max_size=2,
                        checkpoint_dir=ck2, bucket_shapes=False)
    Mirage(cfg4, mesh(1)).fit(graphs)
    cfg5 = MirageConfig(minsup=6, n_partitions=4, max_size=4,
                        checkpoint_dir=ck2, bucket_shapes=True,
                        bucket_c_floor=16, bucket_s_floor=8,
                        bucket_k_floor=8)
    check(Mirage(cfg5, mesh(2)).fit(graphs, resume=True), "adopt")
    print("RESUME-BUCKET-OK")
""")


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_resume_across_bucket_boundaries(tmp_path):
    """A checkpoint written at one bucket boundary resumes at another
    (and with a different worker count, and with bucketing toggled both
    ways) bit-identically to the host oracle."""
    assert "RESUME-BUCKET-OK" in _run_snippet(
        RESUME_BUCKET_SNIPPET, tmp_path / "ck")


# ---------------------------------------------------------------------------
# hypothesis sweep (optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    _HAVE_HYP = True
except ImportError:                                        # pragma: no cover
    _HAVE_HYP = False

if _HAVE_HYP:
    @st.composite
    def small_dbs(draw):
        n = draw(st.integers(6, 14))
        seed = draw(st.integers(0, 2**31 - 1))
        return random_db(n, n_vertices=6, vertex_jitter=1,
                         extra_edge_prob=0.3, n_vlabels=3, n_elabels=2,
                         seed=seed)

    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(small_dbs(),
           st.sampled_from(["fused_interpret", "ref"]),
           st.sampled_from(["psum", "reduce_scatter"]),
           st.sampled_from([1, 2]),
           st.sampled_from([1, 2]))
    def test_conformance_hypothesis(graphs, backend, reduce, scheme, parts):
        minsup = max(2, len(graphs) // 3)
        ref = mine_host(graphs, minsup, max_size=3)
        cfg = MirageConfig(minsup=minsup, n_partitions=parts, scheme=scheme,
                           max_size=3, reduce=reduce,
                           backend=resolve_backend(backend))
        res = Mirage(cfg).fit(graphs)
        assert canon_dist(res) == canon_host(ref), (backend, reduce, scheme)

    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(small_dbs(),
           st.sampled_from([4, 16, 64]),        # bucket_c_floor
           st.sampled_from([2, 8, 32]),         # bucket_s_floor (2 forces
           st.sampled_from([4, 8]),             #   cap-miss retries)
           st.sampled_from(["fused_interpret", "ref"]),
           st.booleans())
    def test_bucketing_never_leaks_hypothesis(graphs, c_floor, s_floor,
                                              k_floor, backend, predict):
        """For ANY bucket-floor family, the bucketed pipeline, the
        unbucketed pipeline, and the host oracle return identical
        frequent sets and supports — padding must never reach verdicts,
        caps, or the compaction."""
        minsup = max(2, len(graphs) // 3)
        ref = mine_host(graphs, minsup, max_size=3)
        base = dict(minsup=minsup, n_partitions=2, max_size=3,
                    backend=resolve_backend(backend),
                    predict_survivors=predict)
        res_b = Mirage(MirageConfig(
            bucket_shapes=True, bucket_c_floor=c_floor,
            bucket_s_floor=s_floor, bucket_k_floor=k_floor,
            **base)).fit(graphs)
        res_u = Mirage(MirageConfig(bucket_shapes=False, **base)).fit(graphs)
        key = (c_floor, s_floor, k_floor, backend, predict)
        assert canon_dist(res_b) == canon_dist(res_u), key
        assert canon_dist(res_b) == canon_host(ref), key
