"""Dense (device) OL algebra vs the exact host miner."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.candgen import generate_candidates
from repro.core.embedding import (build_edge_ol, candidate_meta, join_valid,
                                  level1_ol, local_supports_ref,
                                  materialize_ol, LevelOL)
from repro.core.graphdb import paper_toy_db, random_db
from repro.core.host_miner import frequent_edges, mine_host


def dense_mine_levels(graphs, minsup, max_size, max_embeddings=64, max_occ=None):
    """Single-partition dense mining loop using only embedding.py ops."""
    alphabet, _ = frequent_edges(graphs, minsup)
    triples = sorted({t for c in alphabet.canonical()
                      for t in (c, (c[2], c[1], c[0]))})
    eol = build_edge_ol(graphs, triples, max_occ=max_occ)
    src, dst, em = map(jnp.asarray, (eol.src, eol.dst, eol.mask))

    # F_1 from alphabet (already globally frequent)
    codes = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
    level = level1_ol(codes, eol, max_embeddings=max_embeddings)
    levels = [list(codes)]
    supports = {}
    for c in codes:
        ti = eol.triple_index[c[0][2:]]
        supports[c] = int(np.asarray(eol.mask[ti].any(axis=-1).sum()))

    total_overflow = 0
    k = 1
    while levels[-1] and k < max_size:
        cands = generate_candidates(levels[-1], alphabet)
        if not cands:
            break
        meta = jnp.asarray(candidate_meta(cands, eol))
        sup, _cnt = local_supports_ref(level, src, dst, em, meta)
        sup = np.asarray(sup)
        keep = [i for i in range(len(cands)) if sup[i] >= minsup]
        if not keep:
            break
        keep_meta = jnp.asarray(candidate_meta([cands[i] for i in keep], eol))
        level, over = materialize_ol(level, src, dst, em, keep_meta,
                                     max_embeddings=max_embeddings)
        total_overflow += int(np.asarray(over).sum())
        levels.append([cands[i].code for i in keep])
        for i in keep:
            supports[cands[i].code] = int(sup[i])
        k += 1
    return levels, supports, total_overflow


@pytest.mark.parametrize("graphs,minsup", [
    (paper_toy_db(), 2),
    (random_db(8, n_vertices=6, extra_edge_prob=0.4, n_vlabels=3,
               n_elabels=2, seed=4), 3),
    (random_db(12, n_vertices=8, extra_edge_prob=0.2, n_vlabels=4,
               n_elabels=1, seed=9), 4),
])
def test_dense_matches_host(graphs, minsup):
    ref = mine_host(graphs, minsup, max_size=4)
    levels, supports, overflow = dense_mine_levels(graphs, minsup, max_size=4)
    assert overflow == 0, "M cap must not bind at this scale"
    ref_levels = [set(l) for l in ref.levels]
    got_levels = [set(l) for l in levels]
    assert got_levels == ref_levels
    for code, sup in supports.items():
        assert sup == ref.frequent[code].support, code


def test_paper_toy_dense_13():
    levels, supports, _ = dense_mine_levels(paper_toy_db(), 2, max_size=8)
    assert sum(len(l) for l in levels) == 13


def test_overflow_is_lower_bound():
    """With a tiny M cap, dense supports are a lower bound on true support
    (the documented exactness valve semantics)."""
    graphs = random_db(10, n_vertices=8, extra_edge_prob=0.5, n_vlabels=2,
                       n_elabels=1, seed=2)
    ref = mine_host(graphs, 2, max_size=3)
    _, supports, overflow = dense_mine_levels(graphs, 2, max_size=3,
                                              max_embeddings=2)
    for code, sup in supports.items():
        assert sup <= ref.frequent[code].support


def test_join_valid_backward_semantics():
    """Hand-built: triangle closure on a square + diagonal graph."""
    # parent = path 0-1-2 embedded as (a,b,c); backward edge 2->0 exists
    parent = jnp.asarray(np.array([[[0, 1, 2], [1, 2, 3]]], np.int32))  # (1,2,3)
    pmask = jnp.asarray(np.array([[True, True]]))
    src = jnp.asarray(np.array([[2, 0]], np.int32))   # edge occs (2,0),(0,2)
    dst = jnp.asarray(np.array([[0, 2]], np.int32))
    em = jnp.asarray(np.array([[True, True]]))
    valid = join_valid(parent, pmask, src, dst, em,
                       jnp.int32(2), jnp.int32(0), jnp.int32(0))
    v = np.asarray(valid)
    assert v[0, 0, 0] and not v[0, 0, 1]   # emb (0,1,2): occ (2,0) closes it
    assert not v[0, 1].any()               # emb (1,2,3): no 3->1 edge occ
