"""System-level invariants (hypothesis): the mining result is a pure
function of the database CONTENT — invariant to graph order, partition
count, partition scheme, and reduce schedule."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.graphdb import Graph, random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig


def canon(res):
    return sorted((c, s) for c, s in res.supports.items())


def canon_host(res):
    return sorted((c, i.support) for c, i in res.frequent.items())


@st.composite
def small_dbs(draw):
    n = draw(st.integers(6, 14))
    seed = draw(st.integers(0, 2**31 - 1))
    return random_db(n, n_vertices=6, vertex_jitter=1, extra_edge_prob=0.3,
                     n_vlabels=3, n_elabels=2, seed=seed)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(small_dbs(), st.integers(0, 2**31 - 1))
def test_invariant_to_graph_order(graphs, seed):
    minsup = max(2, len(graphs) // 3)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(graphs))
    shuffled = [graphs[i] for i in perm]
    a = mine_host(graphs, minsup, max_size=3)
    b = mine_host(shuffled, minsup, max_size=3)
    assert canon_host(a) == canon_host(b)


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(small_dbs(), st.sampled_from([1, 2, 4]),
       st.sampled_from([1, 2]), st.sampled_from(["psum", "reduce_scatter"]))
def test_invariant_to_partitioning(graphs, parts, scheme, reduce):
    minsup = max(2, len(graphs) // 3)
    ref = mine_host(graphs, minsup, max_size=3)
    cfg = MirageConfig(minsup=minsup, n_partitions=parts, scheme=scheme,
                       reduce=reduce, max_size=3)
    res = Mirage(cfg).fit(graphs)
    assert canon(res) == canon_host(ref)


def test_empty_and_degenerate_dbs():
    # single graph, minsup 1: everything it contains is frequent
    g = Graph([0, 1, 2], [(0, 1), (1, 2)], [0, 0])
    res = mine_host([g], 1)
    assert len(res.frequent) >= 3            # 2 edges + the path
    # minsup above |G|: nothing is frequent
    res2 = Mirage(MirageConfig(minsup=5, n_partitions=1)).fit([g])
    assert sum(res2.counts()) == 0
