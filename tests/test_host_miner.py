"""Sequential baseline miner vs paper toy example + brute-force oracle."""
import numpy as np
import pytest

from repro.core.dfscode import code_to_graph, min_dfs_code
from repro.core.graphdb import paper_toy_db, pubchem_like_db, random_db
from repro.core.host_miner import mine_host

from oracle import brute_force_frequent, counts_by_level, to_nx, _node_match, _edge_match
import networkx as nx


def test_paper_toy_13_patterns():
    """Paper Fig. 1: 3 graphs, minsup=2 -> exactly 13 frequent subgraphs."""
    res = mine_host(paper_toy_db(), minsup=2)
    assert len(res.frequent) == 13
    # level structure recovered from the figure: 5 edges, 6 2-edge, 2 3-edge
    assert [len(l) for l in res.levels] == [5, 6, 2]
    # the triangle B-D-E (labels B=1, D=3, E=4) must be among them
    tri = min_dfs_code(
        code_to_graph(((0, 1, 1, 0, 3), (1, 2, 3, 0, 4), (2, 0, 4, 0, 1))))
    assert tri in res.frequent
    assert res.frequent[tri].support == 2


def test_paper_toy_frequent_edges():
    """Paper §IV-C1: frequent edges are A-B, B-C, B-D, D-E, B-E."""
    res = mine_host(paper_toy_db(), minsup=2)
    lab = {0: "A", 1: "B", 2: "C", 3: "D", 4: "E"}
    edges = {(lab[c[0][2]], lab[c[0][4]]) for c in res.levels[0]}
    assert edges == {("A", "B"), ("B", "C"), ("B", "D"), ("D", "E"), ("B", "E")}


@pytest.mark.parametrize("seed,minsup", [(0, 3), (1, 2), (2, 4)])
def test_vs_bruteforce_small(seed, minsup):
    graphs = random_db(6, n_vertices=6, vertex_jitter=1, extra_edge_prob=0.4,
                       n_vlabels=3, n_elabels=2, seed=seed)
    max_edges = 4
    res = mine_host(graphs, minsup, max_size=max_edges)
    oracle = brute_force_frequent(graphs, minsup, max_edges)
    got = counts_by_level([0] * 0 or oracle, max_edges)
    mine_counts = [0] * max_edges
    for lvl, codes in enumerate(res.levels):
        mine_counts[lvl] = len(codes)
    assert mine_counts == got, f"per-level counts differ: {mine_counts} vs {got}"
    # every mined pattern is isomorphic to exactly one oracle class with
    # identical support
    for code, info in res.frequent.items():
        P = to_nx(code_to_graph(code))
        matches = [ids for (Q, ids, ne) in oracle
                   if ne == P.number_of_edges() and nx.is_isomorphic(
                       P, Q, node_match=_node_match, edge_match=_edge_match)]
        assert len(matches) == 1
        assert len(matches[0]) == info.support


def test_apriori_antimonotone():
    """support(child) <= support(parent) — the pruning invariant."""
    graphs = random_db(10, n_vertices=7, extra_edge_prob=0.3, n_vlabels=3,
                       n_elabels=1, seed=7)
    res = mine_host(graphs, minsup=2, max_size=4)
    from repro.core.dfscode import is_canonical
    for code, info in res.frequent.items():
        if len(code) == 1:
            continue
        parent_graph = code_to_graph(code[:-1])
        pcode = min_dfs_code(parent_graph)
        assert pcode in res.frequent, "prefix of frequent must be frequent"
        assert info.support <= res.frequent[pcode].support


def test_molecule_like_runs():
    graphs = pubchem_like_db(30, seed=1, avg_edges=12)
    res = mine_host(graphs, minsup=9, max_size=4)
    assert len(res.levels[0]) > 0
    # supports are within [minsup, n_graphs]
    for info in res.frequent.values():
        assert 9 <= info.support <= 30
