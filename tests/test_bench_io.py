"""Bench-harness artifact I/O: a corrupt BENCH_*.json trajectory file
must never be silently destroyed by the merge-and-rewrite in
``benchmarks.run`` (ISSUE-8 bugfix) — it is backed up to ``<path>.bad``
and the run starts a fresh artifact, loudly."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.run import load_existing, parse_row  # noqa: E402


def test_truncated_json_backed_up_not_destroyed(tmp_path, capsys):
    p = tmp_path / "BENCH_kernels.json"
    truncated = '{"kernels/fused_level,C=64": {"us_per_call": 12.5, "der'
    p.write_text(truncated)

    out = load_existing(str(p))

    assert out == {}
    bad = tmp_path / "BENCH_kernels.json.bad"
    assert bad.exists(), "corrupt artifact must be preserved as .bad"
    assert bad.read_text() == truncated, "backup must keep original bytes"
    assert not p.exists(), "the corrupt file was moved, not copied"
    assert "WARNING" in capsys.readouterr().err


def test_valid_json_parses_and_leaves_file_alone(tmp_path):
    p = tmp_path / "BENCH_kernels.json"
    rows = {"kernels/x": {"us_per_call": 1.0, "derived": "n=2"}}
    p.write_text(json.dumps(rows))
    assert load_existing(str(p)) == rows
    assert p.exists()
    assert not (tmp_path / "BENCH_kernels.json.bad").exists()


def test_empty_file_is_fresh_start_without_backup(tmp_path):
    """The writability probe (`open(path, 'a')`) creates empty files —
    an empty artifact is a fresh start, not corruption to back up."""
    p = tmp_path / "BENCH_kernels.json"
    p.write_text("")
    assert load_existing(str(p)) == {}
    assert not (tmp_path / "BENCH_kernels.json.bad").exists()
    p.write_text("   \n")
    assert load_existing(str(p)) == {}
    assert not (tmp_path / "BENCH_kernels.json.bad").exists()


def test_missing_file_is_fresh_start(tmp_path):
    assert load_existing(str(tmp_path / "nope.json")) == {}


def test_parse_row_splits_from_the_right():
    name, rec = parse_row("kernels/fused,C=64,12.5,n=2;m=3")
    assert name == "kernels/fused,C=64"
    assert rec == {"us_per_call": 12.5, "derived": "n=2;m=3"}
