"""Distributed miner (shard_map) vs exact host miner + fault tolerance."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.graphdb import paper_toy_db, pubchem_like_db, random_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig
from repro.core.naive import mine_naive


def assert_same_result(dist, ref):
    assert [set(l) for l in dist.levels] == [set(l) for l in ref.levels]
    for code, sup in dist.supports.items():
        assert sup == ref.frequent[code].support, code


@pytest.mark.parametrize("reduce", ["psum", "reduce_scatter"])
def test_toy_db_single_device(reduce):
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    cfg = MirageConfig(minsup=2, n_partitions=2, max_embeddings=8,
                       reduce=reduce)
    res = Mirage(cfg).fit(graphs)
    assert sum(res.counts()) == 13
    assert_same_result(res, ref)
    assert res.total_overflow == 0


@pytest.mark.parametrize("scheme", [1, 2])
def test_random_db_schemes(scheme):
    graphs = random_db(24, n_vertices=7, extra_edge_prob=0.3, n_vlabels=3,
                       n_elabels=2, seed=11)
    ref = mine_host(graphs, 5, max_size=4)
    cfg = MirageConfig(minsup=5, n_partitions=4, scheme=scheme, max_size=4)
    res = Mirage(cfg).fit(graphs)
    assert_same_result(res, ref)


def test_fractional_minsup():
    graphs = random_db(20, n_vertices=6, seed=3)
    ref = mine_host(graphs, 5, max_size=3)     # ceil(0.25 * 20) = 5
    res = Mirage(MirageConfig(minsup=0.25, n_partitions=4, max_size=3)).fit(graphs)
    assert_same_result(res, ref)


def test_overflow_escalation_keeps_exactness():
    """Start with M=2 (too small); the valve must escalate and stay exact."""
    graphs = random_db(10, n_vertices=8, extra_edge_prob=0.5, n_vlabels=2,
                       n_elabels=1, seed=2)
    ref = mine_host(graphs, 2, max_size=3)
    cfg = MirageConfig(minsup=2, n_partitions=2, max_size=3,
                       max_embeddings=2, escalate_on_overflow=True,
                       max_embeddings_limit=256)
    res = Mirage(cfg).fit(graphs)
    assert res.total_overflow == 0
    assert_same_result(res, ref)


def test_checkpoint_resume(tmp_path):
    graphs = pubchem_like_db(20, seed=5, avg_edges=10)
    ref = mine_host(graphs, 6, max_size=4)
    cfg = MirageConfig(minsup=6, n_partitions=4, max_size=4,
                       checkpoint_dir=str(tmp_path / "ck"))
    full = Mirage(cfg).fit(graphs)
    assert_same_result(full, ref)

    # simulate a crash after level 2: wipe later checkpoints, resume
    from repro.runtime import checkpoint as ckpt
    steps = ckpt.all_steps(cfg.checkpoint_dir)
    assert steps, "mining must have checkpointed"
    import shutil
    for s in steps[1:]:
        shutil.rmtree(os.path.join(cfg.checkpoint_dir, f"step_{s:010d}"))
    resumed = Mirage(cfg).fit(graphs, resume=True)
    assert_same_result(resumed, ref)


def test_make_partitions_rejects_empty_partitions():
    graphs = paper_toy_db()
    from repro.core.partition import make_partitions
    with pytest.raises(ValueError, match="exceeds the database size"):
        make_partitions(graphs, 2, len(graphs) + 1)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_partitions(graphs, 2, 0)


def test_scheme2_spreads_zero_edge_graphs():
    """LPT ties (graphs fully stripped by the edge filter) must not
    starve partitions empty."""
    from repro.core.graphdb import Graph
    from repro.core.partition import make_partitions
    # distinct singleton labels -> every edge is infrequent at minsup 2
    graphs = [Graph([i, i], [(0, 1)], [i]) for i in range(8)]
    part = make_partitions(graphs, 2, 4, scheme=2)
    assert all(len(p) > 0 for p in part.partitions)


def test_mirage_clamps_excess_partitions():
    """n_partitions > |G| auto-clamps (instead of silently padding empty
    partitions) and still matches the oracle."""
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    cfg = MirageConfig(minsup=2, n_partitions=64, max_embeddings=8)
    res = Mirage(cfg).fit(graphs)
    assert_same_result(res, ref)


def test_resume_reuses_checkpointed_partition_count(tmp_path):
    """A resumed run must reproduce the WRITER's partitioning, even when
    the clamp is active (n_partitions > |G|) — the partition count is
    baked into the checkpointed OL store."""
    graphs = pubchem_like_db(5, seed=3, avg_edges=9)
    ref = mine_host(graphs, 2, max_size=4)
    cfg = MirageConfig(minsup=2, n_partitions=16, max_size=2,
                       checkpoint_dir=str(tmp_path / "ck"))
    Mirage(cfg).fit(graphs)                      # clamps to 5 partitions
    cfg2 = MirageConfig(minsup=2, n_partitions=16, max_size=4,
                        checkpoint_dir=str(tmp_path / "ck"))
    res = Mirage(cfg2).fit(graphs, resume=True)
    assert_same_result(res, ref)


def test_mirage_config_rejects_bad_partitions():
    with pytest.raises(ValueError, match="must be >= 1"):
        MirageConfig(minsup=2, n_partitions=0)
    with pytest.raises(ValueError, match="pipeline"):
        MirageConfig(minsup=2, pipeline="bogus")


def test_naive_baseline_duplicates():
    """Hill et al. baseline emits duplicates; MIRAGE's distinct set matches."""
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    naive = mine_naive(graphs, 2, n_iterations=6)
    assert naive.distinct_frequent == len(ref.frequent) == 13
    assert naive.duplicate_ratio > 1.0, "must demonstrate the duplication blowup"


MULTIDEV_SNIPPET = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro.core.graphdb import pubchem_like_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig

    assert jax.device_count() == 8
    from repro.runtime import jax_compat
    mesh = MiningMesh(jax_compat.make_mesh((2, 4), ("data", "model")))
    graphs = pubchem_like_db(48, seed=7, avg_edges=10)
    ref = mine_host(graphs, 12, max_size=4)
    for reduce in ("psum", "reduce_scatter"):
        cfg = MirageConfig(minsup=12, n_partitions=16, max_size=4,
                           reduce=reduce, rebalance=True,
                           rebalance_threshold=1.05)
        res = Mirage(cfg, mesh).fit(graphs)
        assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
        for code, sup in res.supports.items():
            assert sup == ref.frequent[code].support

    # regression: resume AFTER a rebalance permuted the partitions —
    # checkpoints must store the OL store in canonical order
    ck = tempfile.mkdtemp()
    cfg = MirageConfig(minsup=12, n_partitions=16, max_size=2,
                       rebalance=True, rebalance_threshold=1.0,
                       checkpoint_dir=ck)
    Mirage(cfg, mesh).fit(graphs)
    cfg2 = MirageConfig(minsup=12, n_partitions=16, max_size=4,
                        rebalance=True, rebalance_threshold=1.0,
                        checkpoint_dir=ck)
    res = Mirage(cfg2, mesh).fit(graphs, resume=True)
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support
    print("MULTIDEV-OK")
""")


def test_multidevice_mining_subprocess():
    """8 fake devices, 2x4 mesh, 16 partitions, both reduce variants,
    rebalancing enabled — full distributed semantics check."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MULTIDEV-OK" in out.stdout
