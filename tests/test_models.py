"""Per-arch smoke tests (deliverable f) + decode/parallel equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.models.registry import ARCHS, build, count_params, get_smoke_config


def make_batch(cfg, B=2, S=16, seed=0):
    # independent stream per field: batch contents for a seq prefix must
    # be a prefix of the longer batch (decode-consistency tests rely on it)
    r = lambda off: np.random.default_rng(seed + off)
    batch = {"labels": jnp.asarray(r(0).integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            r(1).normal(size=(B, 64, cfg.d_model))[:, :S]
            .astype(np.float32) * 0.02)
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(r(2).integers(0, cfg.vocab, (B, S)))
    if cfg.family in ("audio", "encdec"):
        batch["frames"] = jnp.asarray(
            r(3).normal(size=(B, cfg.encoder_frames, cfg.d_model))
            .astype(np.float32) * 0.02)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    """Reduced config, one forward/loss step: shapes + finite."""
    cfg = get_smoke_config(arch)
    fns = build(cfg)
    params = fns["init"](jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(fns["loss_fn"])(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_param_count_positive(arch):
    cfg = get_smoke_config(arch)
    assert count_params(cfg) > 0
    assert 0 < count_params(cfg, active_only=True) <= count_params(cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill S tokens then decode token S: logits must match the full
    (S+1)-token forward at position S."""
    cfg = get_smoke_config(arch)
    # float32 for exactness; huge capacity so MoE never drops (a dropped
    # token in the full pass legitimately differs from its decode pass)
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    fns = build(cfg)
    params = fns["init"](jax.random.key(1))
    B, S = 2, 8
    rng = np.random.default_rng(3)
    toks = rng.integers(1, cfg.vocab, (B, S + 1))

    def full_batch(n):
        b = make_batch(cfg, B=B, S=n, seed=99)
        if "tokens" in b:
            b["tokens"] = jnp.asarray(toks[:, :n])
        b["labels"] = jnp.asarray(toks[:, :n])
        return b

    logits_full, _ = fns["prefill"](params, full_batch(S + 1))

    # prefill S, then decode the (S+1)-th token.  KV-cache leaves (seq
    # dim == S) need slots for the decode write; recurrent-state leaves
    # are position-free and pass through unchanged.
    logits_pre, cache = fns["prefill"](params, full_batch(S))

    def grow(x):
        if x.ndim >= 3 and x.shape[2] == S:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 4)
            return jnp.pad(x, pad)
        return x
    cache = jax.tree_util.tree_map(grow, cache)

    b1 = make_batch(cfg, B=B, S=1, seed=99)
    if "tokens" in b1:
        b1["tokens"] = jnp.asarray(toks[:, S:S + 1])
    if "embeds" in b1:
        b1["embeds"] = make_batch(cfg, B=B, S=S + 1, seed=99)["embeds"][:, S:]
    if "positions3" in b1:
        b1["positions3"] = jnp.full((3, B, 1), S, jnp.int32)
    logits_dec, _ = fns["decode"](params, cache, b1, jnp.int32(S))

    assert_allclose(np.asarray(logits_dec[:, 0]),
                    np.asarray(logits_full[:, -1]), rtol=2e-4, atol=2e-4)


def test_mlstm_parallel_equals_recurrent():
    from repro.models.xlstm import (init_mlstm, init_mlstm_state, mlstm,
                                    mlstm_decode)
    cfg = get_smoke_config("xlstm_1p3b")
    cfg = dataclasses.replace(cfg, dtype="float32", ssm_chunk=4)
    p = init_mlstm(cfg, jax.random.key(0))
    B, S = 2, 12
    x = jnp.asarray(np.random.default_rng(0).normal(size=(B, S, cfg.d_model))
                    .astype(np.float32) * 0.5)
    y_par = mlstm(p, x, cfg)
    st = init_mlstm_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = mlstm_decode(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    assert_allclose(np.asarray(y_par), np.asarray(y_rec), rtol=1e-4,
                    atol=1e-5)


def test_mamba2_chunked_equals_recurrent():
    from repro.models.ssm import (init_mamba2, init_mamba2_state, mamba2,
                                  mamba2_decode)
    cfg = get_smoke_config("zamba2_2p7b")
    cfg = dataclasses.replace(cfg, dtype="float32", ssm_chunk=4)
    p = init_mamba2(cfg, jax.random.key(0))
    B, S = 2, 12
    x = jnp.asarray(np.random.default_rng(1).normal(size=(B, S, cfg.d_model))
                    .astype(np.float32) * 0.5)
    y_par, state = mamba2(p, x, cfg, return_state=True)
    st = init_mamba2_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = mamba2_decode(p, x[:, t:t + 1], st, cfg)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    assert_allclose(np.asarray(y_par), np.asarray(y_rec), rtol=1e-4,
                    atol=1e-5)
    assert_allclose(np.asarray(state["ssm"]), np.asarray(st["ssm"]),
                    rtol=1e-4, atol=1e-5)


def test_moe_scatter_equals_einsum():
    from repro.models.mlp import init_moe, moe
    cfg = get_smoke_config("phi3p5_moe")
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=4.0)
    p = init_moe(cfg, jax.random.key(0))
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(2, 16, cfg.d_model)).astype(np.float32) * 0.5)
    y1, a1 = moe(p, x, dataclasses.replace(cfg, moe_impl="einsum"))
    y2, a2 = moe(p, x, dataclasses.replace(cfg, moe_impl="scatter"))
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)
    assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_chunked_attention_matches_plain():
    from repro.models.attention import chunked_mha, plain_mha
    rng = np.random.default_rng(0)
    B, S, H, Kv, D = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Kv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Kv, D)).astype(np.float32))
    ref = plain_mha(q, k, v, scale=0.25, causal=True)
    for sched in ("full", "tri"):
        got = chunked_mha(q, k, v, scale=0.25, causal=True, q_chunk=16,
                          kv_chunk=16, schedule=sched)
        assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                        atol=2e-5)
    # sliding window
    ref_w = plain_mha(q, k, v, scale=0.25, causal=True, window=24)
    got_w = chunked_mha(q, k, v, scale=0.25, causal=True, window=24,
                        q_chunk=16, kv_chunk=16)
    assert_allclose(np.asarray(got_w), np.asarray(ref_w), rtol=2e-5,
                    atol=2e-5)
