"""Single-sync level program: wire parity vs the legacy two-program
driver, the one-transfer-per-level contract, on-device LPT, survivor-cap
retry, and donation-mode correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import jax._src.array as _jarr

from repro.core.graphdb import paper_toy_db, random_db
from repro.core.host_miner import mine_host
from repro.core.level_step import lpt_permutation, run_level
from repro.core.mapreduce import MiningMesh, map_reduce_supports
from repro.core.mining import Mirage, MirageConfig, _lpt_order
from repro.core.partition import make_partitions
from repro.core.embedding import build_edge_ol, candidate_meta, level1_ol
from repro.core.candgen import generate_candidates


def _prep(graphs, minsup, n_parts):
    """Phase 1+2 of the driver, host-side (mirrors Mirage.fit prep)."""
    part = make_partitions(graphs, minsup, n_parts)
    alphabet = part.alphabet
    triples = sorted({t for c in alphabet.canonical()
                      for t in (c, (c[2], c[1], c[0]))})
    G = max(len(p) for p in part.partitions)
    eols = [build_edge_ol(p, triples, pad_graphs=G) for p in part.partitions]
    F = max(e.src.shape[-1] for e in eols)

    def padf(a, fill):
        w = [(0, 0)] * (a.ndim - 1) + [(0, F - a.shape[-1])]
        return np.pad(a, w, constant_values=fill)

    src = np.stack([padf(e.src, -1) for e in eols])
    dst = np.stack([padf(e.dst, -1) for e in eols])
    emask = np.stack([padf(e.mask, False) for e in eols])
    codes = [((0, 1, a, e, b),) for (a, e, b) in alphabet.canonical()]
    lvl1 = [level1_ol(codes, e, max_embeddings=max(8, F)) for e in eols]
    pol = np.stack([np.asarray(l.ol) for l in lvl1])
    pmask = np.stack([np.asarray(l.mask) for l in lvl1])
    cands = generate_candidates(codes, alphabet)
    meta = candidate_meta(cands, eols[0])
    return meta, pol, pmask, src, dst, emask, part.minsup


def test_run_level_wire_matches_legacy_supports():
    """The wire's support vector must equal the legacy map_reduce
    round's, for every backend that runs on this host."""
    graphs = random_db(12, n_vertices=6, extra_edge_prob=0.3, n_vlabels=2,
                       n_elabels=2, seed=5)
    meta, pol, pmask, src, dst, emask, minsup = _prep(graphs, 3, 2)
    mesh = MiningMesh.single_device()
    C = meta.shape[0]
    arrs = tuple(map(jnp.asarray, (pol, pmask, src, dst, emask)))
    for backend in ("ref", "interpret", "fused_interpret"):
        gsup_ref, _, _ = map_reduce_supports(
            mesh, meta, *arrs, minsup=minsup, backend=backend)
        out = run_level(mesh, meta, C, *arrs, minsup=minsup,
                        backend=backend, reduce="psum", max_embeddings=16,
                        survivor_cap=C, rebalance=False, threshold=1.25,
                        donate=False)
        np.testing.assert_array_equal(out.wire.gsup, gsup_ref[:C], backend)
        assert out.wire.n_keep == int((gsup_ref[:C] >= minsup).sum())


def test_exactly_one_transfer_per_level():
    """The single-sync contract: mining N levels performs exactly N
    device→host transfers (counted at jax's ArrayImpl fetch point), with
    zero escalations/retries in play."""
    graphs = random_db(24, n_vertices=7, extra_edge_prob=0.3, n_vlabels=3,
                       n_elabels=2, seed=11)
    cfg = MirageConfig(minsup=5, n_partitions=4, max_size=4,
                       predict_survivors=False)

    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = Mirage(cfg).fit(graphs)
    finally:
        _jarr.ArrayImpl._value = orig

    assert sum(st.escalations for st in res.stats) == 0
    assert counts["n"] == len(res.stats), (
        f"{counts['n']} device→host transfers for {len(res.stats)} levels")

    # the legacy pipeline crosses the boundary strictly more often
    counts["n"] = 0
    _jarr.ArrayImpl._value = property(counting)
    try:
        res_legacy = Mirage(
            MirageConfig(minsup=5, n_partitions=4, max_size=4,
                         pipeline="legacy")).fit(graphs)
    finally:
        _jarr.ArrayImpl._value = orig
    assert counts["n"] > len(res_legacy.stats)
    assert sorted(res.supports.items()) == sorted(res_legacy.supports.items())


def test_lpt_permutation_matches_host_balance():
    """Device LPT must produce a valid permutation whose per-worker loads
    match the host LPT's (both are LPT — identical bucket loads even if
    tie order differs)."""
    rng = np.random.default_rng(3)
    for w in (2, 4):
        cost = rng.integers(1, 100, 8).astype(np.float32)
        perm_d = np.asarray(lpt_permutation(jnp.asarray(cost), w))
        perm_h = _lpt_order(cost.astype(np.float64), w)
        assert sorted(perm_d.tolist()) == list(range(8))
        loads_d = cost[perm_d].reshape(w, -1).sum(-1)
        loads_h = cost[perm_h].reshape(w, -1).sum(-1)
        np.testing.assert_allclose(sorted(loads_d), sorted(loads_h))


def test_survivor_cap_miss_retries_exactly(monkeypatch):
    """A survivor cap below the true survivor count must take the
    materialize-only retry path (observable via _materialize_exact) and
    still produce exact results."""
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    # force a cap miss at every level: S=1 while levels keep >1 survivor
    monkeypatch.setattr(Mirage, "_survivor_cap",
                        lambda self, C, Cp, ratios: 1)
    retries = {"n": 0}
    orig = Mirage._materialize_exact

    def counting(self, *a, **kw):
        retries["n"] += 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(Mirage, "_materialize_exact", counting)
    cfg = MirageConfig(minsup=2, n_partitions=2, max_embeddings=8)
    res = Mirage(cfg).fit(graphs)
    assert retries["n"] > 0, "the cap-miss retry branch must fire"
    assert sum(res.counts()) == 13
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code


def test_survivor_cap_rounds_to_bucket_family():
    """Bucketed cap predictions must land in the floor·2^i family,
    clamp at the (bucketed) Cp ceiling, and — the anti-thrash
    property — map near-boundary predictions to ONE bucket instead of
    flipping the compiled program between adjacent raw caps.

    History entries are (n_parents, n_candidates, n_keep) of the
    previous level; the cap predicts from the measured per-parent
    fanout."""
    cfg = MirageConfig(minsup=2, n_partitions=1, bucket_shapes=True,
                       bucket_s_floor=8, bucket_c_floor=16)
    m = Mirage(cfg)
    raw_miner = Mirage(MirageConfig(minsup=2, n_partitions=1,
                                    bucket_shapes=False))
    Cp, C = 64, 60
    family = {8, 16, 32, 64}
    assert m._survivor_cap(C, Cp, []) in family
    for keep_prev in (1, 5, 12, 25, 40, 59):
        hist = [(10, 60, keep_prev)]
        s = m._survivor_cap(C, Cp, hist)
        assert s in family, (keep_prev, s)
        assert s <= Cp
        # never below the unbucketed prediction (a cap that can hold
        # fewer survivors than predicted would guarantee retries)
        raw = raw_miner._survivor_cap(C, Cp, hist)
        assert s >= min(raw, Cp), (keep_prev, s, raw)
    # two near-boundary histories whose RAW caps differ must share a
    # bucket
    raw_a = raw_miner._survivor_cap(C, Cp, [(10, 60, 11)])
    raw_b = raw_miner._survivor_cap(C, Cp, [(10, 60, 12)])
    assert raw_a != raw_b
    assert (m._survivor_cap(C, Cp, [(10, 60, 11)])
            == m._survivor_cap(C, Cp, [(10, 60, 12)]))


def test_survivor_cap_tightens_from_fanout_without_retries():
    """ISSUE-8 regression: the cap must predict from the previous
    level's per-parent FANOUT, not the survival ratio times the current
    (ballooning) candidate count — on a deep expanding run the old
    formula over-padded the child arena while the fanout predictor
    tightens it, and tightening must not buy extra materialize-only
    retries (escalations are ruled out by a roomy M)."""
    graphs = random_db(20, n_vertices=8, extra_edge_prob=0.5,
                       n_vlabels=2, n_elabels=1, seed=7)
    cfg = MirageConfig(minsup=6, n_partitions=1, max_size=5,
                       max_embeddings=64, bucket_shapes=False)
    res = Mirage(cfg).fit(graphs)
    deep = [s for s in res.stats if s.level >= 3]
    assert deep, "run must mine at least one level with cap history"
    assert not any(s.retried for s in res.stats), \
        "the tightened cap must not force materialize-only retries"
    # replay the pre-fix formula (slack x worst recent survival ratio
    # x C) over the run's own history and compare the caps it would
    # have dispatched with
    slack = cfg.survivor_slack
    ratios: list[float] = []
    tighter = 0
    for s in res.stats:
        if ratios:
            r = max(ratios[-2:])
            old = min(s.n_candidates,
                      max(1, int(np.ceil(slack * r * s.n_candidates)) + 16))
            assert s.survivor_cap <= old, (s.level, s.survivor_cap, old)
            if s.survivor_cap < old:
                tighter += 1
            # the cap still covered the real survivors (no miss)
            assert s.n_frequent <= s.survivor_cap
        ratios.append(s.n_frequent / s.n_candidates)
    assert tighter >= 1, "fanout predictor never tightened the cap"


def test_bucketed_cap_miss_retry_stays_in_family(monkeypatch):
    """A forced cap miss under bucketing must take the materialize-only
    retry, re-bucket the survivor store into the S family (so the next
    level's shapes stay cached), and still produce exact results."""
    graphs = paper_toy_db()
    ref = mine_host(graphs, 2)
    monkeypatch.setattr(Mirage, "_survivor_cap",
                        lambda self, C, Cp, ratios: 1)
    retries = {"n": 0}
    orig = Mirage._materialize_exact

    def counting(self, *a, **kw):
        retries["n"] += 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(Mirage, "_materialize_exact", counting)
    cfg = MirageConfig(minsup=2, n_partitions=2, max_embeddings=8,
                       bucket_shapes=True, bucket_s_floor=4,
                       bucket_c_floor=8)
    stores = []
    orig_run = Mirage._level_single_sync

    def spy(self, *a, **kw):
        out = orig_run(self, *a, **kw)
        stores.append(int(out.pol.shape[1]))
        return out

    monkeypatch.setattr(Mirage, "_level_single_sync", spy)
    res = Mirage(cfg).fit(graphs)
    assert retries["n"] > 0, "the cap-miss retry branch must fire"
    for p in stores[:-1]:       # last level may be the empty fixpoint
        assert p % 4 == 0 and (p // 4) & (p // 4 - 1) == 0, (
            f"retried store P={p} escaped the 4·2^i family")
    assert sum(res.counts()) == 13
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code


def test_donation_arena_aliases_without_warning(recwarn):
    """With bucketing aligning consecutive levels' store shapes and
    donation engaged (no retry possible), XLA must actually alias the
    donated parent store — the 'donated buffers were not usable'
    warning is the tripwire for a broken arena."""
    import warnings
    graphs = random_db(16, n_vertices=6, extra_edge_prob=0.3, n_vlabels=2,
                       n_elabels=2, seed=9)
    # floors chosen so EVERY level of this DB lands in one bucket
    # (C <= 128 throughout, level-1 pattern count <= 128, K <= 8):
    # all level programs then share literally one store shape
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=4,
                       max_embeddings=64, escalate_on_overflow=False,
                       predict_survivors=False, donate=True,
                       bucket_shapes=True, bucket_c_floor=128,
                       bucket_s_floor=128, bucket_k_floor=8)
    ref = mine_host(graphs, 4, max_size=4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = Mirage(cfg).fit(graphs)
    unusable = [w for w in caught
                if "donated buffers were not usable" in str(w.message)]
    assert not unusable, [str(w.message)[:200] for w in unusable]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code


def test_donation_mode_correct():
    """With the escalation valve off and no cap prediction the program
    donates its input buffers — results must be unchanged."""
    graphs = random_db(16, n_vertices=6, extra_edge_prob=0.3, n_vlabels=2,
                       n_elabels=2, seed=9)
    ref = mine_host(graphs, 4, max_size=4)
    cfg = MirageConfig(minsup=4, n_partitions=2, max_size=4,
                       max_embeddings=64, escalate_on_overflow=False,
                       predict_survivors=False, donate=True)
    res = Mirage(cfg).fit(graphs)
    assert res.total_overflow == 0
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code
