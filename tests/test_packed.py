"""Bit-packed support path (ISSUE 8, DESIGN.md §12): bitset primitive
units, packed-kernel parity vs the dense kernel and the host oracle,
the packed wire codec, checkpoint packed<->dense cross-resume, and the
multi-worker packed conformance matrix.

The always-on floor is seeded; a Hypothesis sweep over random DBs with
G % 32 != 0 rides along when hypothesis is installed (CI has it, the
dev container may not)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graphdb import random_db
from repro.core.host_miner import mine_host
from repro.core.level_step import (reassemble_wire, wire_checksum,
                                   wire_cost_model, wire_words)
from repro.core.mining import Mirage, MirageConfig
from repro.kernels import bitset
from repro.kernels.ops import level_supports

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYP = True
except ImportError:
    _HAVE_HYP = False


# ---------------------------------------------------------------------------
# bitset primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 31, 32, 33, 64, 100])
def test_pack_unpack_roundtrip_ragged(n):
    rng = np.random.default_rng(n)
    bits = rng.random((3, n)) < 0.5
    words = bitset.pack_bits(bits)
    assert words.dtype == np.uint32
    assert words.shape == (3, bitset.n_words(n))
    np.testing.assert_array_equal(bitset.unpack_bits(words, n), bits)
    # pad bits in the last word are ZERO (the layout contract)
    np.testing.assert_array_equal(words & ~bitset.tail_mask(n), 0)


def test_popcount_matches_python():
    rng = np.random.default_rng(5)
    w = rng.integers(0, 1 << 32, 64, dtype=np.uint32)
    got = bitset.popcount(w)
    want = np.array([bin(int(x)).count("1") for x in w], np.int32)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)
    # the extremes SWAR gets wrong first
    np.testing.assert_array_equal(
        bitset.popcount(np.array([0, 0xFFFFFFFF, 0x80000001], np.uint32)),
        [0, 32, 2])


@pytest.mark.parametrize("n", [1, 17, 32, 45])
def test_packed_any_count_equals_dense(n):
    rng = np.random.default_rng(n)
    bits = rng.random((4, n)) < 0.4
    words = bitset.pack_bits(bits)
    np.testing.assert_array_equal(
        bitset.packed_any_count(words, n), bits.sum(-1).astype(np.int32))
    # ...even after a foreign lane-OR dirtied the pad tail
    dirty = bitset.lane_or(words, ~bitset.tail_mask(n))
    np.testing.assert_array_equal(
        bitset.packed_any_count(dirty, n), bits.sum(-1).astype(np.int32))


def test_lane_and_is_intersection():
    rng = np.random.default_rng(9)
    a = rng.random(70) < 0.5
    b = rng.random(70) < 0.5
    np.testing.assert_array_equal(
        bitset.unpack_bits(
            bitset.lane_and(bitset.pack_bits(a), bitset.pack_bits(b)), 70),
        a & b)


def test_bitset_ops_work_on_jax_arrays():
    bits = np.arange(40) % 3 == 0
    words = bitset.pack_bits(jnp.asarray(bits))
    assert isinstance(words, jnp.ndarray)
    np.testing.assert_array_equal(
        np.asarray(bitset.unpack_bits(words, 40)), bits)
    assert int(bitset.packed_any_count(words, 40)) == int(bits.sum())


def test_support_path_cost_model_packed_undercuts_dense():
    """The modeled support-path bytes behind the CI packed gate: >= 8x
    HBM reduction at word-aligned G, and the packed total must undercut
    dense at every worker count."""
    for w in (1, 2, 4, 8):
        dense = bitset.support_path_cost_model(64, 256, w, packed=False)
        packed = bitset.support_path_cost_model(64, 256, w, packed=True)
        assert dense["hbm_bytes"] / packed["hbm_bytes"] >= 8
        assert packed["total_bytes"] < dense["total_bytes"]
        if w > 1:
            assert packed["collective_bytes"] < dense["collective_bytes"]


# ---------------------------------------------------------------------------
# packed kernel parity (interpret mode on CPU, same program as TPU)
# ---------------------------------------------------------------------------

def _random_level(rng, C=7, P=5, G=20, M=8, K=4, T=6, F=8):
    """Random-but-consistent join inputs, deliberately misaligned
    (C % tile_c != 0, G % 32 != 0)."""
    pol = rng.integers(0, 32, (P, G, M, K)).astype(np.int32)
    pmask = rng.random((P, G, M)) < 0.7
    pol = np.where(rng.random((P, G, M, K)) < 0.15, -1, pol)
    src = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    dst = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    emask = rng.random((T, G, F)) < 0.7
    src = np.where(emask, src, -1)
    dst = np.where(emask, dst, -1)
    meta = np.stack([rng.integers(0, P, C), rng.integers(0, K, C),
                     rng.integers(0, K, C), rng.integers(0, 2, C),
                     rng.integers(0, T, C)], axis=1).astype(np.int32)
    return meta, pol, pmask, src, dst, emask


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_fused_packed_backend_matches_ref_and_dense(seed):
    rng = np.random.default_rng(seed)
    meta, pol, pmask, src, dst, emask = _random_level(rng)
    args = (jnp.asarray(meta), jnp.asarray(pol), jnp.asarray(pmask),
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(emask))
    sup_r, emb_r = level_supports(*args, backend="ref")
    sup_d, emb_d = level_supports(*args, backend="fused_interpret")
    sup_p, emb_p = level_supports(*args, backend="fused_packed_interpret")
    np.testing.assert_array_equal(np.asarray(sup_p), np.asarray(sup_r))
    np.testing.assert_array_equal(np.asarray(sup_p), np.asarray(sup_d))
    np.testing.assert_array_equal(np.asarray(emb_p), np.asarray(emb_r))
    np.testing.assert_array_equal(np.asarray(emb_p), np.asarray(emb_d))


def test_packed_kernel_vbits_match_oracle_bitsets():
    """The kernel's per-graph verdict bitset must be bit-identical to
    the host oracle's (pad tail zero included) — it is the artifact the
    AND+popcount support count is computed from."""
    from repro.core.candgen import schedule_candidates
    from repro.core.embedding import support_bits_ref
    from repro.kernels.ops import fused_level_supports_packed

    rng = np.random.default_rng(4)
    meta, pol, pmask, src, dst, emask = _random_level(rng, C=9, G=37)
    sup_o, _, vbits_o = support_bits_ref(
        jnp.asarray(meta), jnp.asarray(pol), jnp.asarray(pmask),
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(emask))
    sched = schedule_candidates(meta)
    sup_k, _, vbits_k = fused_level_supports_packed(
        jnp.asarray(sched.meta), jnp.asarray(sched.tiles),
        jnp.asarray(pol)[None], jnp.asarray(pmask)[None],
        jnp.asarray(src)[None], jnp.asarray(dst)[None],
        jnp.asarray(emask)[None], interpret=True)
    inv = np.asarray(sched.inv)
    gw = bitset.n_words(37)
    np.testing.assert_array_equal(
        np.asarray(sup_k)[0][inv], np.asarray(sup_o))
    np.testing.assert_array_equal(
        np.asarray(vbits_k)[0][inv][:, :gw], np.asarray(vbits_o))
    # kernel words past n_words(G) (graph-tile padding) must be zero
    np.testing.assert_array_equal(np.asarray(vbits_k)[0][:, gw:], 0)


# ---------------------------------------------------------------------------
# end-to-end conformance: packed == dense == host oracle, G % 32 != 0
# ---------------------------------------------------------------------------

def _conform(graphs, minsup, max_size, **kw):
    ref = mine_host(graphs, minsup, max_size=max_size)
    want = sorted((c, i.support) for c, i in ref.frequent.items())
    base = dict(minsup=minsup, max_size=max_size, **kw)
    packed = Mirage(MirageConfig(**base)).fit(graphs)
    dense = Mirage(MirageConfig(packed_support=False, **base)).fit(graphs)
    assert sorted(packed.supports.items()) == want
    assert sorted(dense.supports.items()) == want
    assert [set(l) for l in packed.levels] == [set(l) for l in dense.levels]


@pytest.mark.parametrize("seed,backend", [(42, None), (42, "fused_interpret"),
                                          (7, None), (7, "fused_interpret")])
def test_packed_conformance_seeded(seed, backend):
    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=seed)
    assert len(graphs) % 32 != 0
    _conform(graphs, 5, 3, n_partitions=4, backend=backend)


def test_packed_default_on_for_single_sync():
    m = Mirage(MirageConfig(minsup=2))
    assert m._packed_support(100) is True
    assert m._packed_support((1 << 16) - 1) is True
    # uint16 wire bound: a DB too large for 2x-uint16 packing stays dense
    assert m._packed_support(1 << 16) is False
    assert Mirage(MirageConfig(
        minsup=2, packed_support=False))._packed_support(100) is False
    assert Mirage(MirageConfig(
        minsup=2, pipeline="legacy"))._packed_support(100) is False
    with pytest.raises(ValueError, match="packed_support"):
        MirageConfig(minsup=2, pipeline="legacy", packed_support=True)


if _HAVE_HYP:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000),
           st.sampled_from([9, 18, 33, 41]),      # all G % 32 != 0
           st.sampled_from([2, 4]))
    def test_packed_conformance_hypothesis(seed, n_graphs, n_parts):
        graphs = random_db(n_graphs, n_vertices=6, extra_edge_prob=0.35,
                           n_vlabels=2, n_elabels=2, seed=seed)
        _conform(graphs, max(2, n_graphs // 6), 3, n_partitions=n_parts)


# ---------------------------------------------------------------------------
# packed wire codec
# ---------------------------------------------------------------------------

def _pack_gsup_host(gsup):
    """Host mirror of the device _pack_wire gsup packing: 2x uint16 per
    int32 word, little end first."""
    u = gsup.astype(np.uint32)
    if u.shape[0] % 2:
        u = np.concatenate([u, np.zeros(1, np.uint32)])
    return (u[0::2] | (u[1::2] << np.uint32(16))).astype(np.int64).astype(
        np.uint32).view(np.int32)


def _make_packed_wire(cp, n_partitions, n_shards, *, seed=0):
    rng = np.random.default_rng(seed)
    gsup = rng.integers(0, 1 << 16, cp).astype(np.int32)
    scalars = np.array([7, 0, 1, 1 << 15, 0], np.int32)
    perm = np.arange(n_partitions, dtype=np.int32)[::-1].copy()
    shards = []
    for s in np.split(gsup, n_shards):
        body = np.concatenate([_pack_gsup_host(s), scalars, perm])
        shards.append(np.concatenate([body, [wire_checksum(body)]]))
    dense_body = np.concatenate([gsup, scalars, perm])
    return np.concatenate(shards).astype(np.int32), dense_body


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("cp", [16, 20])
def test_packed_wire_roundtrip(cp, n_shards):
    """The packed wire (2 supports per word, checksum over PACKED
    words) must reassemble to the exact dense body, odd shard slices
    included."""
    if (cp // n_shards) % 2 and n_shards > 1:
        pytest.skip("odd per-shard slice width with multiple shards")
    n_partitions = 4
    host, dense_body = _make_packed_wire(cp, n_partitions, n_shards)
    assert host.shape[0] == wire_words(cp, n_partitions, n_shards,
                                       packed=True)
    out = reassemble_wire(host, n_partitions, n_shards, packed=True, cp=cp)
    np.testing.assert_array_equal(out, dense_body)


def test_packed_wire_smaller_and_corruption_caught():
    cp, n_partitions = 64, 4
    for n_shards in (1, 2):
        assert wire_words(cp, n_partitions, n_shards, packed=True) < \
            wire_words(cp, n_partitions, n_shards)
        host, _ = _make_packed_wire(cp, n_partitions, n_shards)
        for w in {0, host.shape[0] // 2, host.shape[0] - 1}:
            bad = host.copy()
            bad[w] ^= np.int32(1 << 5)
            assert reassemble_wire(bad, n_partitions, n_shards,
                                   packed=True, cp=cp) is None, (n_shards, w)


def test_packed_wire_cost_model_undercuts_dense():
    for w in (1, 2, 4):
        for sharded in (False, True) if w > 1 else (False,):
            d = wire_cost_model(256, 8, w, reduce="reduce_scatter",
                                sharded=sharded)
            p = wire_cost_model(256, 8, w, reduce="reduce_scatter",
                                sharded=sharded, packed=True)
            assert p["host_bytes"] < d["host_bytes"], (w, sharded)
            assert p["total_bytes"] < d["total_bytes"], (w, sharded)


# ---------------------------------------------------------------------------
# checkpoint: save packed -> resume dense, and vice versa
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("first,second", [(None, False), (False, None)])
def test_checkpoint_cross_resume_packed_dense(tmp_path, first, second):
    """A run checkpointed with the packed path enabled must resume with
    it disabled (and vice versa) bit-identically: checkpoints store the
    canonical OL store (bool masks bit-packed at rest), so the support
    path is free to differ across the save/resume boundary."""
    graphs = random_db(20, n_vertices=8, extra_edge_prob=0.5,
                       n_vlabels=2, n_elabels=1, seed=7)
    ref = mine_host(graphs, 6, max_size=5)
    ck = str(tmp_path / "ck")
    base = dict(minsup=6, n_partitions=4, checkpoint_dir=ck)
    Mirage(MirageConfig(max_size=3, packed_support=first, **base)
           ).fit(graphs)
    res = Mirage(MirageConfig(max_size=5, packed_support=second, **base)
                 ).fit(graphs, resume=True)
    assert res.stats[0].level == 4, "must resume, not restart"
    for code, sup in res.supports.items():
        assert sup == ref.frequent[code].support, code
    assert [set(l) for l in res.levels] == [set(l) for l in ref.levels]


def test_checkpoint_bool_leaves_bitpacked_on_disk(tmp_path):
    from repro.runtime import checkpoint as ckpt

    tree = {"pmask": np.ones((4, 8, 33), bool), "pol": np.zeros(3, np.int32)}
    p = str(tmp_path / "ck")
    ckpt.save_pytree(p, tree)
    with np.load(os.path.join(p, "data.npz")) as z:
        leaves = [z[k] for k in z.files]
    packed_leaves = [a for a in leaves if a.dtype == np.uint8]
    assert len(packed_leaves) == 1, "the bool mask must be stored packed"
    assert packed_leaves[0].nbytes == -(-4 * 8 * 33 // 8)  # 1 bit per flag
    back, _ = ckpt.load_pytree(p)
    np.testing.assert_array_equal(back["pmask"], tree["pmask"])
    assert back["pmask"].dtype == bool


# ---------------------------------------------------------------------------
# multi-worker packed matrix (subprocess: W simulated devices)
# ---------------------------------------------------------------------------

PACKED_MATRIX_SNIPPET = textwrap.dedent("""
    import itertools, os, sys
    W = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={W}")
    import jax
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    assert jax.device_count() == W
    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 5, max_size=3)
    want = sorted((c, i.support) for c, i in ref.frequent.items())
    mesh = MiningMesh(jax_compat.make_mesh((W,), ("w",)))

    for packed, sharded, reduce in itertools.product(
            (None, False), (True, False), ("reduce_scatter", "psum")):
        if sharded and reduce != "reduce_scatter":
            continue
        cfg = MirageConfig(minsup=5, n_partitions=8, max_size=3,
                           reduce=reduce, sharded_wire=sharded,
                           packed_support=packed)
        res = Mirage(cfg, mesh).fit(graphs)
        key = (W, packed, sharded, reduce)
        assert sorted(res.supports.items()) == want, key
    print("PACKED-MATRIX-OK")
""")


def _run_snippet(snippet, *argv, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", snippet, *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.parametrize("workers", [2, 4, 8])
def test_multiworker_packed_matrix(workers):
    """packed (default-on) x sharded-wire x reduce mode, all
    bit-identical to the host oracle at W=2,4,8 — the packed verdict
    gather and the 2x-uint16 wire slice both cross real device
    boundaries here."""
    assert "PACKED-MATRIX-OK" in _run_snippet(PACKED_MATRIX_SNIPPET,
                                              workers)
