"""Serving example: batched prefill + decode with a KV cache, greedy
sampling, for any assigned arch (reduced config).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2.5-14b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build, get_smoke_config

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-14b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--gen-len", type=int, default=24)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
fns = build(cfg)
params = fns["init"](jax.random.key(0))
rng = np.random.default_rng(0)

B, P, G = args.batch, args.prompt_len, args.gen_len
prompts = rng.integers(1, cfg.vocab, (B, P))

batch = {"tokens": jnp.asarray(prompts)}
if cfg.family == "vlm":
    batch = {"embeds": jnp.asarray(rng.normal(size=(B, P, cfg.d_model))
                                   .astype(np.float32) * 0.02),
             "positions3": jnp.broadcast_to(jnp.arange(P)[None, None],
                                            (3, B, P)).astype(jnp.int32)}
if cfg.family in ("audio", "encdec"):
    batch["frames"] = jnp.asarray(
        rng.normal(size=(B, cfg.encoder_frames, cfg.d_model))
        .astype(np.float32) * 0.02)

print(f"=== prefill {B}x{P} on {cfg.name} (reduced) ===")
logits, cache = jax.jit(fns["prefill"])(params, batch)

# widen kv caches to hold the generated tokens
def grow(x):
    if x.ndim >= 3 and x.shape[2] == P:
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, G)
        return jnp.pad(x, pad)
    return x

cache = jax.tree_util.tree_map(grow, cache)
decode = jax.jit(fns["decode"])

tok = jnp.argmax(logits[:, -1], axis=-1)
out_tokens = [np.asarray(tok)]
for t in range(G - 1):
    step_batch = {"tokens": tok[:, None]}
    if cfg.family == "vlm":
        step_batch = {
            "embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
            "positions3": jnp.full((3, B, 1), P + t, jnp.int32)}
    logits, cache = decode(params, cache, step_batch, jnp.int32(P + t))
    tok = jnp.argmax(logits[:, -1], axis=-1)
    out_tokens.append(np.asarray(tok))

gen = np.stack(out_tokens, axis=1)
print(f"greedy generations (token ids), shape {gen.shape}:")
for b in range(B):
    print(f"  req {b}: {prompts[b][-4:].tolist()} -> {gen[b][:12].tolist()}")
print("serving pipeline OK (prefill -> cached decode x%d)" % (G - 1))
