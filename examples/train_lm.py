"""End-to-end driver (deliverable b): train a reduced-config LM for a few
hundred steps on the synthetic pipeline, with mid-run checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py [--arch minicpm-2b]
"""
import argparse
import shutil

import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.models.registry import build, get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="minicpm-2b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
fns = build(cfg)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
opt = AdamWConfig(lr=3e-3, schedule="wsd", warmup_steps=20,
                  total_steps=args.steps)
ckpt = "/tmp/repro_train_example"
shutil.rmtree(ckpt, ignore_errors=True)

print(f"=== training {cfg.name} (reduced) for {args.steps} steps, "
      f"WSD schedule, checkpoint every 50 ===")
half = train_loop(cfg, fns, TrainLoopConfig(
    steps=args.steps // 2, ckpt_every=50, ckpt_dir=ckpt, log_every=20),
    opt, pipe)
print("--- simulated preemption; resuming from latest checkpoint ---")
out = train_loop(cfg, fns, TrainLoopConfig(
    steps=args.steps, ckpt_every=50, ckpt_dir=ckpt, log_every=20),
    opt, pipe, resume=True)

first = np.mean(half["losses"][:10])
last = np.mean(out["losses"][-10:])
print(f"loss: {first:.3f} -> {last:.3f}")
assert last < first, "training must make progress"
shutil.rmtree(ckpt, ignore_errors=True)
