"""Quickstart: mine the paper's own toy database (Fig. 1) and verify the
13 frequent subgraphs, then mine a molecule-like dataset distributed.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.graphdb import paper_toy_db, pubchem_like_db
from repro.core.host_miner import mine_host
from repro.core.mining import Mirage, MirageConfig

# --- 1. the paper's Fig. 1 example, sequential baseline (paper Fig. 3)
graphs = paper_toy_db()
res = mine_host(graphs, minsup=2)
print(f"paper toy DB: {len(res.frequent)} frequent subgraphs "
      f"(paper says 13), per level {[len(l) for l in res.levels]}")
assert len(res.frequent) == 13

# --- 2. the same mine, but through the distributed MIRAGE engine
dist = Mirage(MirageConfig(minsup=2, n_partitions=2)).fit(graphs)
assert dist.counts() == [len(l) for l in res.levels]
print("distributed MIRAGE agrees with the sequential baseline")

# --- 3. a molecule-like dataset (PubChem-style statistics, paper Table I)
mols = pubchem_like_db(60, seed=0, avg_edges=12)
cfg = MirageConfig(minsup=0.25, n_partitions=4, scheme=2,
                   reduce="reduce_scatter", max_size=5)
out = Mirage(cfg).fit(mols)
print(f"molecule-like DB (60 graphs, minsup 25%): "
      f"{sum(out.counts())} frequent subgraphs, per level {out.counts()}")
for st in out.stats:
    print(f"  level {st.level}: {st.n_candidates} candidates -> "
          f"{st.n_frequent} frequent in {st.seconds:.2f}s")
