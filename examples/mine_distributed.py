"""Distributed mining with fault injection: run MIRAGE over 8 simulated
workers, kill it mid-run, and resume from the level checkpoint — the
paper's iterative HDFS handoff, demonstrated end to end.

    PYTHONPATH=src python examples/mine_distributed.py
"""
import os
import shutil
import subprocess
import sys
import textwrap

CKPT = "/tmp/mirage_example_ckpt"

CHILD = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    mesh = MiningMesh(jax_compat.make_mesh((2, 4), ("data", "model")))
    graphs = pubchem_like_db(64, seed=11, avg_edges=14)
    cfg = MirageConfig(minsup=0.12, n_partitions=16, scheme=2,
                       reduce="reduce_scatter",
                       checkpoint_dir={CKPT!r},
                       max_size=int(os.environ.get("MAX_SIZE", "5")))
    res = Mirage(cfg, mesh).fit(graphs, resume=True)
    print("LEVELS:", res.counts())
""")


def run(max_size):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["MAX_SIZE"] = str(max_size)
    r = subprocess.run([sys.executable, "-c", CHILD], env=env,
                       capture_output=True, text=True, timeout=900)
    print(r.stdout.strip())
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


shutil.rmtree(CKPT, ignore_errors=True)

print("=== phase 1: run to level 2, then 'crash' (max_size=2) ===")
out1 = run(max_size=2)
print(f"checkpoints on disk: {sorted(os.listdir(CKPT))}")

print("=== phase 2: restart; resumes from the level-2 checkpoint and "
      "continues mining ===")
out2 = run(max_size=5)
l1 = out1.split("LEVELS:")[-1].strip()
l2 = out2.split("LEVELS:")[-1].strip()
print(f"levels before crash: {l1}  -> after resume: {l2}")
assert len(eval(l2)) > len(eval(l1)), "resume must continue past the crash"
shutil.rmtree(CKPT, ignore_errors=True)
print("fault-injection resume OK")
