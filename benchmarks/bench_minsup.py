"""Paper Fig. 17: runtime vs minimum-support threshold.

Five PubChem-like datasets (Table I statistics, scaled to CPU), minsup
swept 10-20% as in the paper; runtime should fall as minsup rises.
"""
from repro.core.graphdb import pubchem_like_db
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed

DATASETS = {
    "yeast-like": dict(seed=0, n=120, avg_edges=11),
    "nci-h23-like": dict(seed=1, n=80, avg_edges=12),
    "ovcar-8-like": dict(seed=2, n=80, avg_edges=12),
    "sn12c-like": dict(seed=3, n=80, avg_edges=12),
    "p388-like": dict(seed=4, n=90, avg_edges=10),
}


def run() -> list[str]:
    out = []
    for name, d in DATASETS.items():
        graphs = pubchem_like_db(d["n"], seed=d["seed"],
                                 avg_edges=d["avg_edges"])
        for minsup in (0.10, 0.15, 0.20):
            cfg = MirageConfig(minsup=minsup, n_partitions=4, max_size=4)
            res, secs = timed(Mirage(cfg).fit, graphs)
            out.append(row(f"fig17/{name}/minsup={minsup:.2f}", secs,
                           f"frequent={sum(res.counts())}"))
    return out
