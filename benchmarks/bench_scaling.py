"""Paper Fig. 18: runtime and speedup vs number of workers, plus the
per-level pipeline comparison (single-sync device-resident level program
vs the PR-1 two-program driver).

Workers are simulated host devices (subprocess per count so jax re-inits
with the right device pool).  The paper's Yeast/20% setup maps to the
yeast-like dataset.

Two speedup numbers per worker count, both in the derived field:

``measured``  warm wall-clock ratio vs W=1 on THIS host.  Simulated
              workers share the host's cores — on a single-core
              container every "worker" timeshares one CPU, so measured
              speedup cannot exceed 1 no matter how little the workers
              communicate.  It is reported for honesty, not as the
              scaling claim.
``speedup``   the headline: modeled critical-path ratio, from the W=1
              warm per-level timings.  Device map/materialize work is
              partition-parallel (NP >> W, zero cross-partition data
              flow), so it scales 1/W; overlapped host candgen
              (DESIGN.md §11) sits off the critical path up to
              max(dev/W, candgen); non-overlapped host post-processing
              is serial.  Per level:

                  t(W) = max(t_dev/W, t_cand) + t_other

              with t_dev = map_seconds - candgen_seconds (the in-flight
              window minus the host work hidden inside it), t_cand =
              candgen_seconds, t_other = seconds - map_seconds.  The
              same formula at W=1 is the baseline, so the ratio
              isolates the parallelism, not the overlap win.

The deterministic scaling proxy the CI gate checks is the WIRE rows:
modeled per-level per-worker bytes from ``level_step.wire_cost_model``
over the run's actual per-level candidate counts — the sharded wire's
host transfer must shrink with W and undercut the dense all-gather
layout (see benchmarks/check_scaling.py).

``BENCH_SCALING_WORKERS`` (comma-separated, default "1,2,4,8") limits
the worker counts — CI runs "1,2".

``fig18/device_loop_w{1,2}`` compares the whole-run device-resident
loop (DESIGN.md §13) against single_sync on the same run: warm wall
time plus the MEASURED device→host transfer counts (one per run vs one
per level), counted at jax's ArrayImpl fetch point.

The pipeline row measures steady-state (jit-warm) per-level wall time:
each pipeline mines the same database twice in-process and the second
run's mean level time is reported — level shapes recur across runs, so
this isolates the per-iteration dispatch/sync/compute cost the
single-sync program exists to cut (DESIGN.md §8).
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import row

N_PARTITIONS = 16

SNIPPET = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import jax
    from repro.core.buckets import BucketSpec
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    w = int(sys.argv[1])
    mesh = MiningMesh(jax_compat.make_mesh((w,), ("data",)))
    graphs = pubchem_like_db(160, seed=0, avg_edges=11)

    def fit():
        cfg = MirageConfig(minsup=0.20, n_partitions=%(NP)d, max_size=4)
        t0 = time.perf_counter()
        res = Mirage(cfg, mesh).fit(graphs)
        return res, time.perf_counter() - t0, cfg

    fit()                               # cold run: compiles
    res, warm_secs, cfg = fit()         # warm run: steady state
    bk = BucketSpec(cfg.bucket_c_floor, cfg.bucket_s_floor,
                    cfg.bucket_k_floor)
    print(json.dumps({
        "w": w, "secs": warm_secs, "frequent": sum(res.counts()),
        "levels": [{"C": s.n_candidates,
                    "Cp": bk.candidates(s.n_candidates, w),
                    "seconds": s.seconds, "map": s.map_seconds,
                    "cand": s.candgen_seconds} for s in res.stats],
    }))
""") % {"NP": N_PARTITIONS}


PIPELINE_SNIPPET = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    mesh = MiningMesh(jax_compat.make_mesh((4,), ("data",)))
    graphs = pubchem_like_db(160, seed=0, avg_edges=11)
    result = {}
    counts = {}
    for pipeline in ("legacy", "single_sync"):
        best = float("inf")
        for i in range(4):          # run 0 compiles; best-of-3 warm
            cfg = MirageConfig(minsup=0.10, n_partitions=16, max_size=7,
                               pipeline=pipeline)
            res = Mirage(cfg, mesh).fit(graphs)
            per_level = sum(s.seconds for s in res.stats) / len(res.stats)
            if i > 0:
                best = min(best, per_level)
        result[pipeline] = best
        counts[pipeline] = sum(res.counts())
    assert counts["legacy"] == counts["single_sync"], counts
    result["frequent"] = counts["single_sync"]
    print(json.dumps(result))
""")


DEVICE_LOOP_SNIPPET = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import jax
    import jax._src.array as _jarr
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    w = int(sys.argv[1])
    mesh = MiningMesh(jax_compat.make_mesh((w,), ("data",)))
    graphs = pubchem_like_db(160, seed=0, avg_edges=11)

    def fit(pipeline):
        cfg = MirageConfig(minsup=0.20, n_partitions=%(NP)d, max_size=4,
                           pipeline=pipeline)
        m = Mirage(cfg, mesh)
        counts = {"n": 0}
        orig = _jarr.ArrayImpl._value
        def counting(self):
            counts["n"] += 1
            return orig.fget(self)
        _jarr.ArrayImpl._value = property(counting)
        t0 = time.perf_counter()
        try:
            res = m.fit(graphs)
        finally:
            _jarr.ArrayImpl._value = orig
        return res, time.perf_counter() - t0, counts["n"], m

    out = {"w": w}
    for pipeline in ("single_sync", "device_loop"):
        fit(pipeline)                        # cold run: compiles
        res, secs, fetches, m = fit(pipeline)
        out[pipeline] = {"secs": secs, "fetches": fetches,
                         "levels": len(res.stats),
                         "frequent": sum(res.counts())}
        if pipeline == "device_loop":
            assert m.last_device_loop["completed"], m.last_device_loop
    assert out["single_sync"]["frequent"] == out["device_loop"]["frequent"]
    print(json.dumps(out))
""") % {"NP": N_PARTITIONS}


def _modeled_total(levels: list[dict], w: int) -> float:
    """Critical-path model over one run's warm per-level timings (see
    module docstring): max(t_dev/W, t_cand) + t_other per level."""
    total = 0.0
    for lv in levels:
        t_dev = max(lv["map"] - lv["cand"], 0.0)
        t_other = max(lv["seconds"] - lv["map"], 0.0)
        total += max(t_dev / w, lv["cand"]) + t_other
    return total


def _wire_rows(levels: list[dict], w: int) -> list[str]:
    """Modeled per-level per-worker wire bytes at worker count ``w``
    (means over the run's levels), for the three layouts.  The CI gate
    (benchmarks/check_scaling.py) reads these rows."""
    from repro.core.level_step import wire_cost_model

    acc = {"sharded": None, "dense": None, "psum": None}
    for lv in levels:
        costs = {
            "sharded": wire_cost_model(lv["Cp"], N_PARTITIONS, w,
                                       reduce="reduce_scatter"),
            "dense": wire_cost_model(lv["Cp"], N_PARTITIONS, w,
                                     reduce="reduce_scatter", sharded=False),
            "psum": wire_cost_model(lv["Cp"], N_PARTITIONS, w,
                                    reduce="psum"),
        }
        for k, c in costs.items():
            if acc[k] is None:
                acc[k] = dict.fromkeys(c, 0.0)
            for f, v in c.items():
                acc[k][f] += v / len(levels)
    s, d, p = acc["sharded"], acc["dense"], acc["psum"]
    return [row(
        f"fig18/wire_w{w}", s["host_bytes"] * 1e-6,   # row unit is 1e-6
        f"unit=bytes;host={s['host_bytes']:.0f}"
        f";collective={s['collective_bytes']:.0f}"
        f";total={s['total_bytes']:.0f}"
        f";dense_host={d['host_bytes']:.0f}"
        f";dense_total={d['total_bytes']:.0f}"
        f";psum_total={p['total_bytes']:.0f};layout=sharded_rs")]


def run() -> list[str]:
    out = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    workers = [int(x) for x in
               os.environ.get("BENCH_SCALING_WORKERS", "1,2,4,8").split(",")]
    if 1 not in workers:                 # the model needs the baseline
        workers = [1] + workers

    results = {}
    for w in workers:
        r = subprocess.run([sys.executable, "-c", SNIPPET, str(w)],
                           capture_output=True, text=True, env=env,
                           timeout=1800)
        assert r.returncode == 0, r.stderr[-1500:]
        results[w] = json.loads(r.stdout.strip().splitlines()[-1])

    base = results[workers[0]]
    model1 = _modeled_total(base["levels"], 1)
    for w in workers:
        d = results[w]
        modeled = model1 / _modeled_total(base["levels"], w)
        measured = base["secs"] / d["secs"]
        hidden = sum(lv["cand"] for lv in d["levels"])
        out.append(row(
            f"fig18/workers={w}", d["secs"],
            f"speedup={modeled:.2f}x;measured={measured:.2f}x"
            f";model=critical_path;overlap_hidden_s={hidden:.3f}"
            f";frequent={d['frequent']}"))
        out.extend(_wire_rows(base["levels"], w))

    # whole-run device residency (DESIGN.md §13): warm wall time plus
    # MEASURED device→host transfer counts, device_loop vs single_sync
    # on the same run — the per-run vs per-level transfer ledger
    for w in [x for x in workers if x <= 2]:
        r = subprocess.run([sys.executable, "-c", DEVICE_LOOP_SNIPPET,
                            str(w)],
                           capture_output=True, text=True, env=env,
                           timeout=1800)
        assert r.returncode == 0, r.stderr[-1500:]
        d = json.loads(r.stdout.strip().splitlines()[-1])
        ss, dl = d["single_sync"], d["device_loop"]
        out.append(row(
            f"fig18/device_loop_w{w}", dl["secs"],
            f"single_sync_us={ss['secs'] * 1e6:.0f}"
            f";speedup={ss['secs'] / dl['secs']:.2f}x"
            f";transfers_run={dl['fetches']}"
            f";transfers_single_sync={ss['fetches']}"
            f";levels={ss['levels']};frequent={ss['frequent']}"))

    if os.environ.get("BENCH_SCALING_SKIP_PIPELINE"):
        return out
    r = subprocess.run([sys.executable, "-c", PIPELINE_SNIPPET],
                       capture_output=True, text=True, env=env,
                       timeout=1800)
    assert r.returncode == 0, r.stderr[-1500:]
    d = json.loads(r.stdout.strip().splitlines()[-1])
    out.append(row("fig18/level_pipeline_single_sync_w4", d["single_sync"],
                   f"legacy_us={d['legacy'] * 1e6:.0f}"
                   f";speedup={d['legacy'] / d['single_sync']:.2f}x"
                   f";frequent={d['frequent']}"))
    return out
