"""Paper Fig. 18: runtime and speedup vs number of workers, plus the
per-level pipeline comparison (single-sync device-resident level program
vs the PR-1 two-program driver).

Workers are simulated host devices (subprocess per count so jax re-inits
with the right device pool).  The paper's Yeast/20% setup maps to the
yeast-like dataset; speedup is reported relative to the smallest count.
The absolute CPU numbers are not TPU predictions — the *shape* (near-
linear until partition granularity binds) is the reproduction.

The pipeline row measures steady-state (jit-warm) per-level wall time:
each pipeline mines the same database twice in-process and the second
run's mean level time is reported — level shapes recur across runs, so
this isolates the per-iteration dispatch/sync/compute cost the
single-sync program exists to cut (DESIGN.md §8).
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import row

SNIPPET = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    w = int(sys.argv[1])
    mesh = MiningMesh(jax_compat.make_mesh((w,), ("data",)))
    graphs = pubchem_like_db(160, seed=0, avg_edges=11)
    cfg = MirageConfig(minsup=0.20, n_partitions=16, max_size=4)
    miner = Mirage(cfg, mesh)
    t0 = time.perf_counter()
    res = miner.fit(graphs)
    print(json.dumps({"w": w, "secs": time.perf_counter() - t0,
                      "frequent": sum(res.counts())}))
""")


PIPELINE_SNIPPET = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.core.graphdb import pubchem_like_db
    from repro.core.mapreduce import MiningMesh
    from repro.core.mining import Mirage, MirageConfig
    from repro.runtime import jax_compat

    mesh = MiningMesh(jax_compat.make_mesh((4,), ("data",)))
    graphs = pubchem_like_db(160, seed=0, avg_edges=11)
    result = {}
    counts = {}
    for pipeline in ("legacy", "single_sync"):
        best = float("inf")
        for i in range(4):          # run 0 compiles; best-of-3 warm
            cfg = MirageConfig(minsup=0.10, n_partitions=16, max_size=7,
                               pipeline=pipeline)
            res = Mirage(cfg, mesh).fit(graphs)
            per_level = sum(s.seconds for s in res.stats) / len(res.stats)
            if i > 0:
                best = min(best, per_level)
        result[pipeline] = best
        counts[pipeline] = sum(res.counts())
    assert counts["legacy"] == counts["single_sync"], counts
    result["frequent"] = counts["single_sync"]
    print(json.dumps(result))
""")


def run() -> list[str]:
    out = []
    base = None
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    for w in (1, 2, 4, 8):
        r = subprocess.run([sys.executable, "-c", SNIPPET, str(w)],
                           capture_output=True, text=True, env=env,
                           timeout=1800)
        assert r.returncode == 0, r.stderr[-1500:]
        d = json.loads(r.stdout.strip().splitlines()[-1])
        if base is None:
            base = d["secs"]
        out.append(row(f"fig18/workers={w}", d["secs"],
                       f"speedup={base / d['secs']:.2f}x"
                       f";frequent={d['frequent']}"))

    r = subprocess.run([sys.executable, "-c", PIPELINE_SNIPPET],
                       capture_output=True, text=True, env=env,
                       timeout=1800)
    assert r.returncode == 0, r.stderr[-1500:]
    d = json.loads(r.stdout.strip().splitlines()[-1])
    out.append(row("fig18/level_pipeline_single_sync_w4", d["single_sync"],
                   f"legacy_us={d['legacy'] * 1e6:.0f}"
                   f";speedup={d['legacy'] / d['single_sync']:.2f}x"
                   f";frequent={d['frequent']}"))
    return out
