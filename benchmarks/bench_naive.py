"""Paper Table III: MIRAGE vs Hill et al. [32] (no duplicate elimination).

Reports wall time AND the duplicate blow-up (candidates evaluated,
patterns emitted with duplicates) that explains the paper's 6-7x gap.
"""
from repro.core.graphdb import pubchem_like_db, random_db
from repro.core.host_miner import mine_host
from repro.core.naive import mine_naive

from .common import row, timed


def run() -> list[str]:
    out = []
    cases = [
        ("yeast-like", pubchem_like_db(60, seed=0, avg_edges=10), 0.4, 4),
        ("p388-like", pubchem_like_db(60, seed=4, avg_edges=10), 0.4, 4),
        ("nci-h23-like", pubchem_like_db(60, seed=1, avg_edges=10), 0.4, 4),
        # low label diversity = many symmetric patterns = the duplicate
        # explosion the paper's Table III gap comes from
        ("low-label-diversity",
         random_db(16, n_vertices=8, extra_edge_prob=0.6, n_vlabels=2,
                   n_elabels=1, seed=3), 0.25, 5),
    ]
    for name, graphs, ms_frac, n_iter in cases:
        minsup = int(ms_frac * len(graphs))

        res, t_mirage = timed(mine_host, graphs, minsup, max_size=n_iter)
        naive, t_naive = timed(mine_naive, graphs, minsup, n_iter)

        n_mirage = len(res.frequent)
        assert naive.distinct_frequent == n_mirage, (
            "both must find the same distinct frequent set")
        out.append(row(f"table3/{name}/mirage", t_mirage,
                       f"frequent={n_mirage};candidates="
                       f"{sum(res.n_candidates)}"))
        out.append(row(
            f"table3/{name}/hill-et-al", t_naive,
            f"emitted={sum(naive.per_level_emitted)};duplicate_ratio="
            f"{naive.duplicate_ratio:.2f};speedup="
            f"{t_naive / max(t_mirage, 1e-9):.1f}x"))
    return out
