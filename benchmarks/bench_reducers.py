"""Paper Fig. 19: runtime vs reducer count.

Hadoop's reducer-count knob becomes the reduce collective's shard
layout.  We compare the two reduce schedules (psum = every worker owns
every key; reduce_scatter = each worker owns C/W keys, Hadoop-style) and
report measured wall time plus the modeled wire bytes per level from
``level_step.wire_cost_model`` — which is what the knob actually
controls at pod scale.  The reduce_scatter row carries both layouts of
the level wire: dense (support vector all-gathered and fetched whole by
every worker) and sharded (each worker keeps + transfers only its C/W
slice, DESIGN.md §11) — the sharded layout is the single-sync default.
"""
from repro.core.graphdb import pubchem_like_db
from repro.core.level_step import wire_cost_model
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed


def run() -> list[str]:
    graphs = pubchem_like_db(120, seed=3, avg_edges=11)
    out = []
    W, NP = 256, 8
    for reduce in ("psum", "reduce_scatter"):
        cfg = MirageConfig(minsup=0.20, n_partitions=NP, reduce=reduce,
                           max_size=4)
        res, secs = timed(Mirage(cfg).fit, graphs)
        c_total = sum(s.n_candidates for s in res.stats)
        # modeled per-worker bytes at pod scale (W=256), summed over the
        # run's candidate volume
        cost = wire_cost_model(c_total, NP, W, reduce=reduce)
        derived = (f"candidates={c_total}"
                   f";wire_bytes@256={cost['total_bytes']:.0f}")
        if reduce == "reduce_scatter":
            dense = wire_cost_model(c_total, NP, W, reduce=reduce,
                                    sharded=False)
            derived += (f";dense_wire_bytes@256={dense['total_bytes']:.0f}"
                        f";layout=sharded")
        out.append(row(f"fig19/reduce={reduce}", secs,
                       derived + f";frequent={sum(res.counts())}"))
    return out
