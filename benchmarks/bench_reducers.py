"""Paper Fig. 19: runtime vs reducer count.

Hadoop's reducer-count knob becomes the reduce collective's shard
layout.  We compare the two reduce schedules (psum = every worker owns
every key; reduce_scatter = each worker owns C/W keys, Hadoop-style) and
report measured wall time plus the analytic wire bytes per level, which
is what the knob actually controls at pod scale.
"""
from repro.core.graphdb import pubchem_like_db
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed


def run() -> list[str]:
    graphs = pubchem_like_db(120, seed=3, avg_edges=11)
    out = []
    for reduce in ("psum", "reduce_scatter"):
        cfg = MirageConfig(minsup=0.20, n_partitions=8, reduce=reduce,
                           max_size=4)
        res, secs = timed(Mirage(cfg).fit, graphs)
        c_total = sum(s.n_candidates for s in res.stats)
        # wire bytes per worker for W workers (ring factors):
        #   psum: 2(W-1)/W * C * 4B ; rs+ag: (W-1)/W * C * (4+1)B
        W = 256
        psum_b = 2 * (W - 1) / W * c_total * 4
        rs_b = (W - 1) / W * c_total * (4 + 1)
        est = psum_b if reduce == "psum" else rs_b
        out.append(row(f"fig19/reduce={reduce}", secs,
                       f"candidates={c_total};wire_bytes@256={est:.0f};"
                       f"frequent={sum(res.counts())}"))
    return out
