"""Paper Table II: runtime vs database size (100K-1000K graphs in the
paper; scaled 1000x down for CPU with the same 25-30 edge statistics —
the shape of the curve, near-linear in |G|, is the reproduction)."""
from repro.core.graphdb import pubchem_like_db
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed


def run() -> list[str]:
    out = []
    for n in (100, 250, 500, 750, 1000):
        graphs = pubchem_like_db(n, seed=7, avg_edges=10)
        cfg = MirageConfig(minsup=0.30, n_partitions=8, max_size=3)
        res, secs = timed(Mirage(cfg).fit, graphs)
        out.append(row(f"table2/graphs={n}", secs,
                       f"frequent={sum(res.counts())}"))
    return out
