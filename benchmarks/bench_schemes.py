"""Paper Table IV: partition scheme 1 (graph-count balanced) vs scheme 2
(edge-balanced), including the paper's skewed synthetic (half ~15 edges,
half ~30 edges) where scheme 2's load balancing shows up."""
import numpy as np

from repro.core.graphdb import pubchem_like_db
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed


def skewed_db(n, seed=0):
    half = n // 2
    small = pubchem_like_db(half, seed=seed, avg_edges=8)
    big = pubchem_like_db(n - half, seed=seed + 1, avg_edges=22)
    rng = np.random.default_rng(seed)
    both = small + big
    order = rng.permutation(len(both))
    return [both[i] for i in order]


def run() -> list[str]:
    out = []
    cases = {
        "uniform": pubchem_like_db(120, seed=9, avg_edges=11),
        "skewed": skewed_db(120, seed=10),
    }
    for name, graphs in cases.items():
        for scheme in (1, 2):
            cfg = MirageConfig(minsup=0.20, n_partitions=8, scheme=scheme,
                               max_size=4, rebalance=False)
            res, secs = timed(Mirage(cfg).fit, graphs)
            imb = max((s.imbalance for s in res.stats), default=1.0)
            out.append(row(f"table4/{name}/scheme={scheme}", secs,
                           f"frequent={sum(res.counts())};"
                           f"max_imbalance={imb:.2f}"))
    return out
