"""CI gate for anytime mining & the invariant auditor (DESIGN.md §14).

    PYTHONPATH=src python -m benchmarks.check_recovery BENCH_kernels.json

Wall time on shared CI runners is too noisy to gate on, so the gate
checks DETERMINISTIC invariants recorded by ``bench_kernels``:

  1. every ``kernels/auditor_overhead_w{W}`` row must hold the audit's
     modeled bytes under 5% of the level's modeled critical path (wire
     + candidate-meta upload) — the auditor must stay effectively free;
  2. ``kernels/recovery_partial_deadline`` must read ``partial=1`` AND
     ``prefix_ok=1``: a deadline-bound run returned a PartialResult
     that re-verified as an exact prefix of the host oracle;
  3. ``kernels/recovery_hang_detect`` must show the 999s injected stall
     detected in bounded time (``detect_s`` within 60x the pinned 0.5s
     phase deadline — generous, but a hung detector would read 999)
     with full parity after recovery;
  4. ``kernels/recovery_one_fault`` (the §10 row) must still record
     exactly one replayed fault — the §14 machinery must not have
     perturbed plain checkpoint recovery.
"""
import json
import re
import sys

MAX_OVERHEAD = 0.05
MAX_DETECT_S = 30.0


def _field(derived: str, key: str) -> float:
    m = re.search(rf"(?:^|;){key}=([0-9.]+)", derived)
    if m is None:
        raise SystemExit(f"missing '{key}' in derived field: {derived!r}")
    return float(m.group(1))


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    with open(path) as f:
        rows = json.load(f)

    failures = []

    overhead_rows = sorted(r for r in rows
                           if r.startswith("kernels/auditor_overhead_w"))
    if not overhead_rows:
        raise SystemExit(f"{path}: no kernels/auditor_overhead_w* rows "
                         f"— run bench_kernels first")
    overheads = {}
    for name in overhead_rows:
        ov = _field(rows[name]["derived"], "overhead")
        overheads[name] = ov
        if not ov < MAX_OVERHEAD:
            failures.append(
                f"{name}: modeled audit overhead {ov:.4f} is not under "
                f"the {MAX_OVERHEAD:.0%} critical-path budget")

    for required in ("kernels/recovery_partial_deadline",
                     "kernels/recovery_hang_detect",
                     "kernels/recovery_one_fault"):
        if required not in rows:
            raise SystemExit(f"{path}: missing {required} row")

    pd = rows["kernels/recovery_partial_deadline"]["derived"]
    if _field(pd, "partial") != 1.0:
        failures.append("recovery_partial_deadline: no PartialResult "
                        "was returned")
    if _field(pd, "prefix_ok") != 1.0:
        failures.append("recovery_partial_deadline: the partial result "
                        "is NOT a verified prefix of the host oracle")

    hd = rows["kernels/recovery_hang_detect"]["derived"]
    detect = _field(hd, "detect_s")
    if not detect < MAX_DETECT_S:
        failures.append(
            f"recovery_hang_detect: {detect:.2f}s to detect the "
            f"injected stall (bound {MAX_DETECT_S:.0f}s)")
    if _field(hd, "parity") != 1.0:
        failures.append("recovery_hang_detect: post-recovery result "
                        "lost parity with the host oracle")

    of = rows["kernels/recovery_one_fault"]["derived"]
    if _field(of, "events") != 1.0:
        failures.append("recovery_one_fault: plain checkpoint recovery "
                        "no longer records exactly one event")

    if failures:
        for f_ in failures:
            print(f"RECOVERY GATE FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    summary = ", ".join(f"{n.rsplit('_', 1)[1]}={v:.1%}"
                        for n, v in overheads.items())
    print(f"recovery gate OK: auditor overhead {summary} "
          f"(budget {MAX_OVERHEAD:.0%}), deadline partial is a verified "
          f"prefix, hang detected in {detect:.2f}s")


if __name__ == "__main__":
    main()
