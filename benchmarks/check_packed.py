"""CI gate for the bit-packed support path (the `tier1` job).

    PYTHONPATH=src python -m benchmarks.check_packed BENCH_kernels.json

Wall time on shared CI runners is too noisy to gate on, so the gate
checks the DETERMINISTIC proxy recorded by ``bench_kernels``: the
modeled support-path bytes (verdict HBM lanes + reduce_scatter verdict
collective + gsup wire slice, from ``bitset.support_path_cost_model``)
for the dense int32 path vs the bit-packed path, at the default shape
across worker counts.  Two invariants:

  1. every ``kernels/packed_support_path_w{W}`` row must show the
     packed bytes undercutting the dense baseline by at least 8x (the
     ISSUE-8 acceptance floor; the layout's asymptotic win is 32x on
     the HBM term);
  2. the packed-parity row must exist and read ``exact`` — the byte win
     only counts if the packed kernel stayed bit-identical.
"""
import json
import re
import sys


def _field(derived: str, key: str) -> float:
    m = re.search(rf"(?:^|;){key}=([0-9.]+)", derived)
    if m is None:
        raise SystemExit(f"missing '{key}' in derived field: {derived!r}")
    return float(m.group(1))


MIN_REDUCTION = 8.0


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    with open(path) as f:
        rows = json.load(f)

    worker_rows = sorted(r for r in rows
                         if r.startswith("kernels/packed_support_path_w"))
    if not worker_rows:
        raise SystemExit(f"{path}: no kernels/packed_support_path_w* rows "
                         f"— run bench_kernels first")
    if "kernels/packed_parity" not in rows:
        raise SystemExit(f"{path}: missing kernels/packed_parity row")

    failures = []
    if rows["kernels/packed_parity"]["derived"] != "exact":
        failures.append(
            f"packed parity is "
            f"{rows['kernels/packed_parity']['derived']!r}, not 'exact'")
    reductions = {}
    for name in worker_rows:
        derived = rows[name]["derived"]
        dense = _field(derived, "dense_bytes")
        packed = _field(derived, "packed_bytes")
        reduction = _field(derived, "reduction")
        reductions[name] = reduction
        if not packed < dense:
            failures.append(
                f"{name}: packed {packed:.0f}B >= dense {dense:.0f}B")
        if reduction < MIN_REDUCTION:
            failures.append(
                f"{name}: support-path byte reduction {reduction:.2f}x "
                f"below the {MIN_REDUCTION:.0f}x floor")

    if failures:
        for f_ in failures:
            print(f"PACKED GATE FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    summary = ", ".join(f"{n.rsplit('_', 1)[1]}={r:.1f}x"
                        for n, r in reductions.items())
    print(f"packed gate OK: support-path byte reduction {summary} "
          f"(floor {MIN_REDUCTION:.0f}x), parity exact")


if __name__ == "__main__":
    main()
