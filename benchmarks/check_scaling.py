"""CI gate for multi-worker scaling (the `scaling` job).

    PYTHONPATH=src python -m benchmarks.check_scaling BENCH_kernels.json

Wall time on shared CI runners is too noisy to gate on, so the gate
checks the DETERMINISTIC scaling proxy: the modeled per-level wire
bytes recorded by ``bench_scaling`` (`fig18/wire_w{1,2}` rows, from
``level_step.wire_cost_model`` over the run's actual candidate counts).
Two invariants, both of which the dense all-gather wire violates and
the sharded wire restores:

  1. each worker's device→host wire bytes per level at W=2 must be
     STRICTLY below the W=1 baseline (the wire itself must shard — a
     dense wire holds them equal, a regression grows them);
  2. the sharded layout's total bytes at W=2 must be strictly below the
     dense all-gather layout's at W=2 (the collective cut must not be
     given back on the host link).

Also asserts the fig18 speedup rows exist and the modeled critical-path
speedup at W=2 exceeds 1.0 — the ROADMAP item-1 exit criterion as
recorded in the artifact.
"""
import json
import re
import sys


def _field(derived: str, key: str) -> float:
    m = re.search(rf"(?:^|;){key}=([0-9.]+)", derived)
    if m is None:
        raise SystemExit(f"missing '{key}' in derived field: {derived!r}")
    return float(m.group(1))


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json"
    with open(path) as f:
        rows = json.load(f)

    for need in ("fig18/wire_w1", "fig18/wire_w2", "fig18/workers=1",
                 "fig18/workers=2"):
        if need not in rows:
            raise SystemExit(f"{path}: missing row {need!r} — run "
                             f"bench_scaling (fig18) first")

    host1 = _field(rows["fig18/wire_w1"]["derived"], "host")
    host2 = _field(rows["fig18/wire_w2"]["derived"], "host")
    total2 = _field(rows["fig18/wire_w2"]["derived"], "total")
    dense2 = _field(rows["fig18/wire_w2"]["derived"], "dense_total")
    speedup2 = _field(rows["fig18/workers=2"]["derived"], "speedup")

    failures = []
    if not host2 < host1:
        failures.append(
            f"per-worker host wire bytes did not shrink: W=2 {host2:.0f}B "
            f">= W=1 {host1:.0f}B (the wire must shard)")
    if not total2 < dense2:
        failures.append(
            f"sharded total {total2:.0f}B >= dense all-gather baseline "
            f"{dense2:.0f}B at W=2")
    if not speedup2 > 1.0:
        failures.append(
            f"modeled critical-path speedup at W=2 is {speedup2:.2f}x "
            f"(must exceed 1.0)")

    if failures:
        for f_ in failures:
            print(f"SCALING GATE FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"scaling gate OK: host wire {host1:.0f}B -> {host2:.0f}B "
          f"per worker (W=1 -> W=2), sharded total {total2:.0f}B < dense "
          f"{dense2:.0f}B, modeled speedup {speedup2:.2f}x")


if __name__ == "__main__":
    main()
