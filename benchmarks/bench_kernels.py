"""Kernel-level microbench: the embedding-join (support counting) hot
path — ref (XLA) wall time per candidate at mining-realistic shapes, and
interpret-mode parity spot check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import level_supports
from repro.kernels.ref import embedding_join_ref

from .common import row, timed


def _inputs(C=64, P=16, G=256, M=32, K=6, T=24, F=24, seed=0):
    rng = np.random.default_rng(seed)
    pol = rng.integers(0, 32, (P, G, M, K)).astype(np.int32)
    pmask = rng.random((P, G, M)) < 0.6
    src = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    dst = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    emask = rng.random((T, G, F)) < 0.6
    meta = np.stack([rng.integers(0, P, C), rng.integers(0, K, C),
                     rng.integers(0, K, C), rng.integers(0, 2, C),
                     rng.integers(0, T, C)], 1).astype(np.int32)
    return tuple(map(jnp.asarray, (meta, pol, pmask, src, dst, emask)))


def run() -> list[str]:
    out = []
    args = _inputs()
    fn = jax.jit(lambda *a: level_supports(*a, backend="ref"))
    fn(*args)[0].block_until_ready()        # compile
    (sup, emb), secs = timed(lambda: jax.block_until_ready(fn(*args)))
    C = args[0].shape[0]
    out.append(row("kernels/embedding_join_ref(64cand,256graph)",
                   secs, f"per_candidate_us={secs / C * 1e6:.1f}"))

    # parity: interpret-mode Pallas vs ref on a slice
    small = _inputs(C=4, G=16, M=8, K=4, T=4, F=8, seed=1)
    s_ref, e_ref = level_supports(*small, backend="ref")
    s_k, e_k = level_supports(*small, backend="interpret", tile_g=8,
                              tile_c=4)
    assert np.array_equal(np.asarray(s_ref), np.asarray(s_k))
    out.append(row("kernels/pallas_interpret_parity", 0.0, "exact"))
    return out
