"""Kernel-level microbench: the map-phase hot path.

Compares the three executable variants of one level's support counting at
the mining-realistic default shape (C=64 candidates, G=256 graphs):

  * ``ref``        — pure-XLA oracle (wall time per candidate)
  * two-launch     — seed device pipeline (join kernel -> (C, G) HBM
                     intermediates -> reduce kernel), interpret mode
  * fused          — single-launch fused kernel + parent-grouped
                     schedule (DESIGN.md §6), interpret mode

Two candidate distributions are timed: ``grouped`` is the realistic one
(candgen emits parent-clustered candidates — every frequent pattern
yields one candidate per alphabet partner, so blocks share parent/edge
OL tiles); ``scattered`` is the adversarial all-distinct case, where the
adaptive schedule must collapse to tile_c=1 and the fused win reduces to
launch-count + eliminated (C, G) intermediates.

Interpret-mode wall times are CPU proxies (no Mosaic), but the
launch-count and HBM-traffic differences they reflect are structural;
the ``bytes_moved`` rows are the analytic HBM-traffic model for each
path, hardware-independent.  Fused parity vs ref is asserted bit-exact.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.candgen import schedule_candidates
from repro.kernels.ops import level_supports

from .common import row, timed

DEFAULT_SHAPE = dict(C=64, P=16, G=256, M=32, K=6, T=24, F=24)
TILE_C, TILE_G = 8, 128


def _inputs(C=64, P=16, G=256, M=32, K=6, T=24, F=24, seed=0,
            grouped=False):
    rng = np.random.default_rng(seed)
    pol = rng.integers(0, 32, (P, G, M, K)).astype(np.int32)
    pmask = rng.random((P, G, M)) < 0.6
    src = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    dst = rng.integers(0, 32, (T, G, F)).astype(np.int32)
    emask = rng.random((T, G, F)) < 0.6
    meta = np.stack([rng.integers(0, P, C), rng.integers(0, K, C),
                     rng.integers(0, K, C), rng.integers(0, 2, C),
                     rng.integers(0, T, C)], 1).astype(np.int32)
    if grouped:
        # parent-clustered candidates, as candgen emits them: 8 parents,
        # 8 sibling candidates each sharing the adjoined triple
        meta[:, 0] = np.repeat(np.arange(C // 8) % P, 8)
        meta[:, 4] = np.repeat(rng.integers(0, T, C // 8), 8)
    return tuple(map(jnp.asarray, (meta, pol, pmask, src, dst, emask)))


def bytes_moved_estimates(C, G, M, K, F, *, n_tiles, Cs,
                          tile_g=TILE_G):
    """(two_launch_bytes, fused_bytes) HBM-traffic model for one level.

    Per graph tile, a candidate (or candidate block) streams its parent
    OL tile, parent mask, and the edge-OL triple tiles:
      tile_bytes = TG·(M·K·4 + M·1 + F·4 + F·4 + F·1)
    two-launch:  C tile-streams per graph tile, plus writing then
                 re-reading matched/count (C, G) int32 and writing (C,).
    fused:       one tile-stream per candidate block per graph tile,
                 plus writing (Cs,) sup/emb once (output blocks are
                 revisited in VMEM across the G sweep).
    """
    n_g = (G + tile_g - 1) // tile_g
    tile_bytes = tile_g * (M * K * 4 + M + F * 4 + F * 4 + F)
    two_launch = (C * n_g * tile_bytes          # join input streaming
                  + 2 * C * G * 4               # join writes matched/count
                  + 2 * C * G * 4               # reduce re-reads them
                  + 2 * C * 4)                  # reduce writes sup/emb
    fused = (n_tiles * n_g * tile_bytes          # block-shared streaming
             + 2 * Cs * 4)                       # sup/emb written once
    return two_launch, fused


def _time_pair(args, label, result):
    """Time two-launch vs fused on one input set (generator of rows).

    Timings land in ``result[label]`` as (two_launch_s, fused_s).  Rows
    are yielded as they are measured so the harness retains them even if
    a later gate assertion fires.
    """
    C = args[0].shape[0]
    two = lambda: jax.block_until_ready(level_supports(
        *args, backend="interpret", tile_g=TILE_G, tile_c=TILE_C))
    two()                                    # compile
    (s_two, e_two), secs_two = timed(two, repeats=3)
    yield row(f"kernels/two_launch_interpret({label})",
              secs_two, f"per_candidate_us={secs_two / C * 1e6:.1f}")

    fused = lambda: jax.block_until_ready(level_supports(
        *args, backend="fused_interpret", tile_g=TILE_G, tile_c=TILE_C))
    fused()                                  # compile
    (s_f, e_f), secs_f = timed(fused, repeats=3)
    yield row(f"kernels/fused_single_launch({label})",
              secs_f, f"per_candidate_us={secs_f / C * 1e6:.1f}")

    s_ref, e_ref = level_supports(*args, backend="ref")
    assert np.array_equal(np.asarray(s_f), np.asarray(s_ref))
    assert np.array_equal(np.asarray(e_f), np.asarray(e_ref))
    assert np.array_equal(np.asarray(s_two), np.asarray(s_ref))
    yield row(f"kernels/fused_vs_two_launch({label})", 0.0,
              f"speedup=x{secs_two / secs_f:.2f}")
    result[label] = (secs_two, secs_f)


def run():
    """Yields CSV rows (generator, so measured rows survive gate
    failures — the harness records everything emitted before a raise)."""
    args = _inputs(**DEFAULT_SHAPE)
    C = args[0].shape[0]

    fn = jax.jit(lambda *a: level_supports(*a, backend="ref"))
    fn(*args)[0].block_until_ready()        # compile
    (s_ref, e_ref), secs = timed(lambda: jax.block_until_ready(fn(*args)))
    yield row("kernels/embedding_join_ref(64cand,256graph)",
              secs, f"per_candidate_us={secs / C * 1e6:.1f}")

    # realistic parent-clustered candidates — the headline comparison
    grouped = _inputs(**DEFAULT_SHAPE, grouped=True)
    result = {}
    yield from _time_pair(grouped, "64cand,256graph,grouped", result)
    secs_two_g, secs_f_g = result["64cand,256graph,grouped"]
    # the acceptance gate: fused must beat the seed two-launch path
    assert secs_f_g < secs_two_g, (
        f"fused ({secs_f_g:.4f}s) must beat two-launch ({secs_two_g:.4f}s)")

    # adversarial all-distinct candidates — adaptive schedule falls back
    # to tile_c=1.  Sanity guard only: interpret-mode CPU timings carry
    # scheduler noise, so the margin is generous (the structural claim —
    # no blow-up without grouping — is what it protects).
    yield from _time_pair(args, "64cand,256graph,scattered", result)
    secs_two_s, secs_f_s = result["64cand,256graph,scattered"]
    assert secs_f_s < secs_two_s * 1.5, (
        f"fused fallback ({secs_f_s:.4f}s) regressed vs two-launch "
        f"({secs_two_s:.4f}s)")

    # analytic HBM traffic with the REAL schedules
    d = DEFAULT_SHAPE
    for label, a in (("grouped", grouped), ("scattered", args)):
        sched = schedule_candidates(np.asarray(a[0]), TILE_C)
        b_two, b_fused = bytes_moved_estimates(
            d["C"], d["G"], d["M"], d["K"], d["F"],
            n_tiles=sched.n_tiles, Cs=sched.meta.shape[0])
        yield row(f"kernels/bytes_moved({label})", 0.0,
                  f"two_launch={b_two} fused={b_fused} "
                  f"reduction=x{b_two / b_fused:.2f}")

    # parity spot-check on a misaligned slice (C%TC != 0, G%TG != 0)
    small = _inputs(C=7, G=20, M=8, K=4, T=4, F=8, seed=1)
    s_r, e_r = level_supports(*small, backend="ref")
    s_k, _e_k = level_supports(*small, backend="interpret", tile_g=4,
                               tile_c=4)
    s_fk, e_fk = level_supports(*small, backend="fused_interpret",
                                tile_g=4, tile_c=4)
    assert np.array_equal(np.asarray(s_r), np.asarray(s_k))
    assert np.array_equal(np.asarray(s_r), np.asarray(s_fk))
    assert np.array_equal(np.asarray(e_r), np.asarray(e_fk))
    yield row("kernels/pallas_interpret_parity", 0.0, "exact")

    yield from _bench_packed()
    yield from _bench_bucketing()
    yield from _bench_recovery()
    yield from _bench_device_loop()
    yield from _bench_anytime()


def _bench_packed():
    """Bit-packed support path (DESIGN.md §12): time the packed fused
    kernel against the dense fused kernel (interpret-mode CPU proxy,
    parity asserted bit-exact), then record the DETERMINISTIC modeled
    support-path bytes — verdict HBM lanes, reduce_scatter verdict
    collective, gsup wire slice — dense vs packed at the default shape.
    The byte rows are what ``benchmarks/check_packed.py`` gates on
    (wall time on shared runners is noise; the byte model is not)."""
    from repro.kernels.bitset import support_path_cost_model

    d = DEFAULT_SHAPE
    grouped = _inputs(**d, grouped=True)
    C = grouped[0].shape[0]
    dense = lambda: jax.block_until_ready(level_supports(
        *grouped, backend="fused_interpret", tile_g=TILE_G, tile_c=TILE_C))
    packed = lambda: jax.block_until_ready(level_supports(
        *grouped, backend="fused_packed_interpret", tile_g=TILE_G,
        tile_c=TILE_C))
    dense(); packed()                        # compile
    (s_d, e_d), secs_d = timed(dense, repeats=3)
    (s_p, e_p), secs_p = timed(packed, repeats=3)
    assert np.array_equal(np.asarray(s_p), np.asarray(s_d))
    assert np.array_equal(np.asarray(e_p), np.asarray(e_d))
    yield row("kernels/fused_packed(64cand,256graph,grouped)", secs_p,
              f"per_candidate_us={secs_p / C * 1e6:.1f};"
              f"dense_ratio={secs_p / max(secs_d, 1e-9):.2f}")

    # misaligned parity (G % 32 != 0, C % tile_c != 0): the ragged-tail
    # gmask contract, checked where the bench artifact can prove it
    small = _inputs(C=7, G=20, M=8, K=4, T=4, F=8, seed=1)
    s_r, e_r = level_supports(*small, backend="ref")
    s_pk, e_pk = level_supports(*small, backend="fused_packed_interpret",
                                tile_g=32, tile_c=4)
    assert np.array_equal(np.asarray(s_r), np.asarray(s_pk))
    assert np.array_equal(np.asarray(e_r), np.asarray(e_pk))
    yield row("kernels/packed_parity", 0.0, "exact")

    for w in (1, 2, 4, 8):
        db = support_path_cost_model(d["C"], d["G"], w, packed=False)
        pb = support_path_cost_model(d["C"], d["G"], w, packed=True)
        yield row(f"kernels/packed_support_path_w{w}", 0.0,
                  f"dense_bytes={db['total_bytes']:.0f};"
                  f"packed_bytes={pb['total_bytes']:.0f};"
                  f"reduction={db['total_bytes'] / pb['total_bytes']:.2f}")


def _bench_bucketing():
    """Shape bucketing (DESIGN.md §9): mine a deep path DB COLD and
    report per-level wall time with compiles included, plus the number
    of distinct level programs actually compiled, bucketed vs
    unbucketed.  The unbucketed pipeline compiles one program per level
    (the vertex axis K grows every iteration); bucketing collapses that
    to a handful, which is where the cold-run win comes from — the
    steady-state compute is identical masked work."""
    import time

    from repro.core import level_step
    from repro.core.graphdb import Graph
    from repro.core.mining import Mirage, MirageConfig

    def path(n):
        return Graph(np.zeros(n, np.int32),
                     np.stack([np.arange(n - 1), np.arange(1, n)], 1),
                     np.zeros(n - 1, np.int32))

    graphs = [path(9) for _ in range(6)]
    per_level = {}
    for bucket in (True, False):
        compiled = set()
        orig = level_step._level_program

        def traced(*key, _orig=orig, _compiled=compiled):
            fn = _orig(*key)

            def wrapper(*args):
                _compiled.add((key, tuple(np.shape(a) for a in args)))
                return fn(*args)
            return wrapper

        level_step._level_program = traced
        try:
            t0 = time.perf_counter()
            res = Mirage(MirageConfig(minsup=6, n_partitions=2,
                                      max_size=8,
                                      bucket_shapes=bucket)).fit(graphs)
            secs = time.perf_counter() - t0
        finally:
            level_step._level_program = orig
        n_levels = len(res.stats)
        per_level[bucket] = secs / n_levels
        tag = "on" if bucket else "off"
        yield row(f"kernels/level_bucketing_{tag}", secs / n_levels,
                  f"compiles={len(compiled)};levels={n_levels}")
    yield row("kernels/level_bucketing_cold_speedup", 0.0,
              f"speedup=x{per_level[False] / per_level[True]:.2f}")


def _bench_recovery():
    """Recovery overhead (DESIGN.md §10): wall time of a supervised
    mining run, clean vs with one injected in-kernel fault at level 3
    (retry from the level-2 checkpoint; zero backoff so the row measures
    replay + checkpoint-load cost, not sleep).  Warm caches — both runs
    reuse the already-compiled level programs, isolating the recovery
    machinery itself."""
    import shutil
    import tempfile
    import time

    from repro.core.graphdb import random_db
    from repro.core.mining import MirageConfig
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults

    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)

    def mine(schedule):
        root = tempfile.mkdtemp(prefix="bench-recovery-")
        try:
            if schedule:
                faults.install(faults.FaultSchedule.parse(schedule))
            sup = MiningSupervisor(
                MirageConfig(minsup=5, n_partitions=2, max_size=5,
                             checkpoint_dir=root),
                SupervisorConfig(backoff_base=0.0, sleep_fn=lambda s: None))
            t0 = time.perf_counter()
            res = sup.mine(graphs)
            secs = time.perf_counter() - t0
            return res, sup, secs
        finally:
            faults.clear()
            shutil.rmtree(root, ignore_errors=True)

    mine(None)                                  # warm the jit caches
    res_c, _, clean = mine(None)
    res_f, sup_f, faulted = mine("kernel_fault@3")
    assert len(sup_f.events) == 1, sup_f.events
    assert sorted(res_f.supports.items()) == sorted(res_c.supports.items())
    yield row("kernels/recovery_clean", clean,
              f"levels={len(res_c.stats)}")
    yield row("kernels/recovery_one_fault", faulted,
              f"replayed_from_ckpt=1;events={len(sup_f.events)}")
    yield row("kernels/recovery_overhead", 0.0,
              f"overhead=x{faulted / max(clean, 1e-9):.2f}")


def _bench_anytime():
    """Anytime mining (DESIGN.md §14): the deadline→partial cut, hang
    detection latency, and the invariant auditor's modeled overhead.

    ``recovery_partial_deadline`` times the full partial-result path —
    DeadlineExceeded, checkpoint walk, decode, whole-prefix re-audit —
    and records whether the cut is a verified prefix of the host
    oracle.  ``recovery_hang_detect`` injects a 999s stall under a
    pinned 0.5s phase deadline and records the watchdog's measured
    detection latency (parsed from the supervisor's own fault event).
    ``auditor_overhead_w*`` is the deterministic byte model
    ``check_recovery.py`` gates under 5% of the per-level critical
    path."""
    import re
    import shutil
    import tempfile
    import time

    from repro.core.auditor import audit_overhead_model
    from repro.core.graphdb import random_db
    from repro.core.host_miner import mine_host
    from repro.core.mining import Mirage, MirageConfig, PartialResult
    from repro.core.supervisor import MiningSupervisor, SupervisorConfig
    from repro.runtime import faults
    from repro.runtime.watchdog import Watchdog

    graphs = random_db(10, seed=5, n_vertices=9, n_vlabels=2, n_elabels=1)
    ref = mine_host(graphs, 5, max_size=5)

    def cfg(root):
        return MirageConfig(minsup=5, n_partitions=2, max_size=5,
                            checkpoint_dir=root)

    # deadline → verified partial cut (checkpoints pre-populated by a
    # clean audited run, as a real deadline-bound rerun would find them)
    root = tempfile.mkdtemp(prefix="bench-anytime-")
    try:
        Mirage(cfg(root)).fit(graphs)
        sup = MiningSupervisor(
            cfg(root), SupervisorConfig(on_exhausted="partial",
                                        sleep_fn=lambda s: None))
        t0 = time.perf_counter()
        res = sup.mine(graphs, deadline_s=1e-6)
        cut_s = time.perf_counter() - t0
        n = len(res.levels)
        prefix_ok = (isinstance(res, PartialResult) and res.audited
                     and [set(l) for l in res.levels]
                     == [set(l) for l in ref.levels[:n]]
                     and all(s == ref.frequent[c].support
                             for c, s in res.supports.items()))
        yield row("kernels/recovery_partial_deadline", cut_s,
                  f"partial={int(isinstance(res, PartialResult))};"
                  f"prefix_ok={int(prefix_ok)};"
                  f"last_level={res.last_level}")
    finally:
        faults.clear()
        shutil.rmtree(root, ignore_errors=True)

    # hang detection: a 999s injected stall under a 0.5s phase deadline
    root = tempfile.mkdtemp(prefix="bench-anytime-")
    try:
        faults.install(faults.FaultSchedule.parse("hang@3:secs=999"))
        sup = MiningSupervisor(
            cfg(root), SupervisorConfig(sleep_fn=lambda s: None),
            watchdog=Watchdog(phase_default=0.5))
        t0 = time.perf_counter()
        res = sup.mine(graphs)
        total = time.perf_counter() - t0
        hang_events = [e for e in sup.events if e.kind == "hang"]
        assert hang_events, sup.events
        m = re.search(r"after ([0-9.]+)s", hang_events[0].detail)
        detect = float(m.group(1)) if m else float("nan")
        parity = int(sorted(res.supports.items())
                     == sorted((c, i.support)
                               for c, i in ref.frequent.items()))
        yield row("kernels/recovery_hang_detect", total,
                  f"detect_s={detect:.2f};events={len(hang_events)};"
                  f"parity={parity}")
    finally:
        faults.clear()
        shutil.rmtree(root, ignore_errors=True)

    # the auditor's modeled byte overhead on the per-level critical path
    for w in (1, 2, 4, 8):
        m = audit_overhead_model(1024, 8, w)
        yield row(f"kernels/auditor_overhead_w{w}", 0.0,
                  f"overhead={m['overhead']:.4f};"
                  f"audit_bytes={m['audit_bytes']:.0f};"
                  f"path_bytes={m['path_bytes']:.0f}")


def _bench_device_loop():
    """Whole-run device residency (DESIGN.md §13): warm per-level
    driver time and MEASURED device→host transfer counts, the
    lax.while_loop run program vs the per-level single-sync driver on
    the same DB.  Interpret-mode CPU wall time mostly reflects kernel
    compute, so the structural claim this row tracks is the transfer
    ledger (one fetch per RUN vs one per LEVEL); the timing ratio is
    recorded for the trajectory, not gated."""
    import time

    import jax._src.array as _jarr

    from repro.core.graphdb import random_db
    from repro.core.mining import Mirage, MirageConfig

    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)

    def mine(pipeline):
        cfg = MirageConfig(minsup=3, n_partitions=2, max_size=4,
                           backend="ref", pipeline=pipeline)
        m = Mirage(cfg)
        counts = {"n": 0}
        orig = _jarr.ArrayImpl._value

        def counting(self):
            counts["n"] += 1
            return orig.fget(self)

        _jarr.ArrayImpl._value = property(counting)
        t0 = time.perf_counter()
        try:
            res = m.fit(graphs)
        finally:
            _jarr.ArrayImpl._value = orig
        return res, time.perf_counter() - t0, counts["n"]

    out = {}
    for pipeline in ("single_sync", "device_loop"):
        mine(pipeline)                          # warm the jit caches
        out[pipeline] = mine(pipeline)
    res_ss, secs_ss, n_ss = out["single_sync"]
    res_dl, secs_dl, n_dl = out["device_loop"]
    assert sorted(res_dl.supports.items()) == sorted(
        res_ss.supports.items())
    assert n_dl == 1, f"device_loop fetched {n_dl} times"
    n_levels = len(res_ss.stats)
    yield row("kernels/device_loop_per_level", secs_dl / n_levels,
              f"single_sync_us={secs_ss / n_levels * 1e6:.0f}"
              f";speedup=x{secs_ss / secs_dl:.2f}"
              f";transfers_run={n_dl};transfers_single_sync={n_ss}"
              f";levels={n_levels}")
