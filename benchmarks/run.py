"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig17,table3] \
        [--json [BENCH_kernels.json]]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).  With
``--json`` the same rows are also written to a machine-readable file
mapping name -> {us_per_call, derived}, so the perf trajectory can be
tracked across PRs instead of scraped from stdout.
"""
import argparse
import json
import os
import sys
import traceback

from . import (bench_dbsize, bench_kernels, bench_minsup, bench_naive,
               bench_partitions, bench_reducers, bench_scaling,
               bench_schemes)

SUITES = {
    "fig17_minsup": bench_minsup,
    "table2_dbsize": bench_dbsize,
    "fig18_scaling": bench_scaling,
    "fig19_reducers": bench_reducers,
    "fig20_partitions": bench_partitions,
    "table4_schemes": bench_schemes,
    "table3_naive": bench_naive,
    "kernels": bench_kernels,
}


def load_existing(path: str) -> dict:
    """Read the bench-trajectory artifact about to be merged into.

    A missing or empty file is a fresh start (the writability probe in
    ``main`` creates empty files).  A file that EXISTS but fails to
    parse is a real artifact in an unknown state — silently treating it
    as ``{}`` used to let the merge-and-rewrite below destroy the whole
    perf trajectory.  Instead the corrupt bytes are moved aside to
    ``<path>.bad`` (preserved for forensics) and the run continues with
    a fresh artifact, loudly.
    """
    try:
        with open(path) as f:
            text = f.read()
    except FileNotFoundError:
        return {}
    if not text.strip():
        return {}
    try:
        return json.loads(text)
    except json.JSONDecodeError as e:
        bad = path + ".bad"
        os.replace(path, bad)
        print(f"WARNING: existing {path} is not valid JSON ({e}); "
              f"moved it to {bad} and starting a fresh artifact",
              file=sys.stderr)
        return {}


def parse_row(line: str) -> tuple[str, dict]:
    """Invert common.row: ``name,us_per_call,derived`` -> (name, record).

    Names may contain commas (shape suffixes); derived never does, so
    split from the right.
    """
    name, us, derived = line.rsplit(",", 2)
    return name, {"us_per_call": float(us), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated suite-name substrings")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON {name: {us_per_call, "
                         "derived}} (default path: BENCH_kernels.json)")
    args = ap.parse_args()
    picks = [s for s in args.only.split(",") if s]

    existing: dict[str, dict] = {}
    if args.json:
        # probe writability up front (append mode — truncating now would
        # destroy the artifact if a suite later crashes): an unwritable
        # path must fail before the (long) suites run, not after
        open(args.json, "a").close()
        # and MERGE over the existing artifact: a partial run (--only)
        # refreshes its own rows and preserves every other suite's —
        # the fig17/18/19 trajectory rows the ROADMAP cites must survive
        # kernel-only CI regenerations.  A corrupt artifact is backed
        # up to .bad, never silently overwritten (see load_existing).
        existing = load_existing(args.json)

    print("name,us_per_call,derived")
    failed = []
    records: dict[str, dict] = {}
    for name, mod in SUITES.items():
        if picks and not any(p in name for p in picks):
            continue
        try:
            for line in mod.run():
                print(line, flush=True)
                row_name, rec = parse_row(line)
                records[row_name] = rec
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        merged = {**existing, **records}
        with open(args.json, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
        print(f"wrote {len(records)} rows to {args.json} "
              f"({len(merged)} total after merge)", file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
