"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig17,table3]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
"""
import argparse
import sys
import traceback

from . import (bench_dbsize, bench_kernels, bench_minsup, bench_naive,
               bench_partitions, bench_reducers, bench_scaling,
               bench_schemes)

SUITES = {
    "fig17_minsup": bench_minsup,
    "table2_dbsize": bench_dbsize,
    "fig18_scaling": bench_scaling,
    "fig19_reducers": bench_reducers,
    "fig20_partitions": bench_partitions,
    "table4_schemes": bench_schemes,
    "table3_naive": bench_naive,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated suite-name substrings")
    args = ap.parse_args()
    picks = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if picks and not any(p in name for p in picks):
            continue
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
