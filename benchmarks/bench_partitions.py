"""Paper Fig. 20: runtime vs partition count — the paper's key systems
finding: mapper cost is exponential in partition size, shuffle cost only
linear, so partitions ≫ workers wins until key-space overhead bites."""
from repro.core.graphdb import pubchem_like_db
from repro.core.mining import Mirage, MirageConfig

from .common import row, timed


def run() -> list[str]:
    graphs = pubchem_like_db(160, seed=5, avg_edges=11)
    out = []
    for parts in (2, 4, 8, 16, 32):
        cfg = MirageConfig(minsup=0.20, n_partitions=parts, max_size=4)
        res, secs = timed(Mirage(cfg).fit, graphs)
        out.append(row(f"fig20/partitions={parts}", secs,
                       f"frequent={sum(res.counts())}"))
    return out
