"""Shared benchmark utilities."""
from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 1, **kw):
    """(last_result, best-of-``repeats`` seconds).  Callers that assert
    on comparative timings should pass repeats >= 3 to tame scheduler
    noise; the default single shot keeps long suites cheap."""
    best = float("inf")
    out = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def row(name: str, seconds: float, derived: str = "") -> str:
    """CSV contract: name,us_per_call,derived."""
    return f"{name},{seconds * 1e6:.0f},{derived}"
