"""Shared benchmark utilities."""
from __future__ import annotations

import time


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def row(name: str, seconds: float, derived: str = "") -> str:
    """CSV contract: name,us_per_call,derived."""
    return f"{name},{seconds * 1e6:.0f},{derived}"
