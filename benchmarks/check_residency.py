"""CI gate for whole-run device residency (DESIGN.md §13).

    PYTHONPATH=src python -m benchmarks.check_residency

Wall time is too noisy to gate on, so the gate counts the DETERMINISTIC
quantity the device_loop pipeline exists to minimize: device→host
transfers per mining run, measured at jax's ``ArrayImpl._value`` fetch
point (the same tracer tests/test_compile_cache.py uses).  Three
invariants on the same DB:

  1. the single_sync baseline fetches once per mined level (the PR-2
     wire contract) — this is the per-LEVEL floor device_loop removes;
  2. a checkpoint-free device_loop run fetches exactly ONCE — the
     end-of-run wire; nothing else crosses the boundary;
  3. a chunked run (``device_loop_ckpt_every=1``) stays within the
     ``ChunkCadence`` budget: one wire fetch per chunk plus two store
     fetches per checkpoint saved (``max_fetches() + 2`` with the
     final-state save).

All three runs must agree with the host oracle bit-for-bit — a fetch
count only counts if the mining stayed exact.  Run under
``JAX_LOG_COMPILES=1`` in CI so the compile log rides along as an
artifact next to the fetch counts.
"""
import sys
import tempfile

import jax._src.array as _jarr

sys.path.insert(0, "src")  # noqa: E402 — runnable as a script too

from repro.core.graphdb import random_db            # noqa: E402
from repro.core.host_miner import mine_host          # noqa: E402
from repro.core.mining import Mirage, MirageConfig   # noqa: E402
from repro.runtime.checkpoint import ChunkCadence    # noqa: E402


def count_fetches(cfg, graphs):
    """Mine under ``cfg`` counting every ArrayImpl materialization."""
    miner = Mirage(cfg)
    counts = {"n": 0}
    orig = _jarr.ArrayImpl._value

    def counting(self):
        counts["n"] += 1
        return orig.fget(self)

    _jarr.ArrayImpl._value = property(counting)
    try:
        res = miner.fit(graphs)
    finally:
        _jarr.ArrayImpl._value = orig
    return res, counts["n"], miner


def main() -> None:
    graphs = random_db(18, n_vertices=6, extra_edge_prob=0.35,
                       n_vlabels=3, n_elabels=2, seed=42)
    ref = mine_host(graphs, 3, max_size=4)
    canon = sorted((c, i.support) for c, i in ref.frequent.items())
    base = dict(minsup=3, n_partitions=2, max_size=4, backend="ref")

    failures = []

    def check(tag, res, cond, detail):
        if sorted(res.supports.items()) != canon:
            failures.append(f"{tag}: supports diverge from the host "
                            f"oracle")
        if not cond:
            failures.append(f"{tag}: {detail}")

    # 1. per-level baseline: single_sync fetches the wire once per level
    res_ss, n_ss, _ = count_fetches(MirageConfig(**base), graphs)
    levels = len(res_ss.stats)
    check("single_sync", res_ss, n_ss == levels,
          f"{n_ss} fetches for {levels} levels (expected one per level)")

    # 2. the residency contract: one fetch for the WHOLE run
    res_dl, n_dl, m = count_fetches(
        MirageConfig(pipeline="device_loop", **base), graphs)
    check("device_loop", res_dl,
          m.last_device_loop["completed"] and n_dl == 1,
          f"{n_dl} fetches for the whole run (contract: exactly 1)")

    # 3. chunked checkpoints stay inside the cadence budget
    cadence = ChunkCadence(1, base["max_size"], 1)
    budget = cadence.max_fetches() + 2   # + final-state save
    with tempfile.TemporaryDirectory() as ckdir:
        res_ck, n_ck, m_ck = count_fetches(
            MirageConfig(pipeline="device_loop", device_loop_ckpt_every=1,
                         checkpoint_dir=ckdir, **base), graphs)
    check("device_loop+ckpt", res_ck,
          m_ck.last_device_loop["completed"] and n_ck <= budget,
          f"{n_ck} fetches exceed the {cadence.n_chunks}-chunk budget "
          f"of {budget}")

    if failures:
        for f_ in failures:
            print(f"RESIDENCY GATE FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"residency gate OK: single_sync={n_ss} fetches "
          f"({levels} levels), device_loop=1 fetch/run, "
          f"chunked={n_ck} fetches within the {budget} budget "
          f"({cadence.n_chunks} chunks)")


if __name__ == "__main__":
    main()
